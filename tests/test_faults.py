"""Fault-injection harness + crash-consistent recovery tests.

The robustness claim under test: a chain that dies *mid-flight* (not a
dead host driver — PR 3 covered that) leaves a torn device state that
``fsck`` can classify and repair, and a repaired re-issue converges
bit-exactly to the host oracle.  The interpreter is the authority on
fault semantics (``machine.run(..., faults=...)``); the pallas backend
keeps bit-exact parity on the one fault it supports (fuel truncation).

The heart of the file is the exhaustive cut-point sweeps: every step of
a displacement bubble and of a migration lap is killed once, and every
resulting torn state must be classified, repaired, and re-driven to the
oracle's exact answer.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import assembler, machine, programs
from repro.core import faults as faults_mod
from repro.core.engine import ChainEngine
from repro.kvstore import fsck, hopscotch, store
from repro.rdma import failure

TERMINAL_SET = (programs.SET_UPDATED, programs.SET_INSERTED,
                programs.SET_DISPLACED)
TERMINAL_MIG = (programs.MIG_MOVED, programs.MIG_DISCARDED)


def _one_shard_mesh():
    return Mesh(np.array(jax.devices()[:1]), ("kv",))


# --- FaultPlan basics --------------------------------------------------------

def test_fault_plan_row_roundtrip():
    p = faults_mod.FaultPlan(jnp.int32(3), jnp.int32(-1), jnp.int32(0),
                             jnp.int32(-1))
    row = p.as_rows()
    assert row.shape == (faults_mod.FIELDS,)
    q = faults_mod.FaultPlan.from_row(row)
    for a, b in zip(p, q):
        assert int(a) == int(b)
    assert bool(p.active())
    assert not bool(faults_mod.FaultPlan.none().active())


def test_kill_lap_plan_shape_and_semantics():
    p = faults_mod.FaultPlan.kill_lap(6, lap=2, step=9)
    kill = np.asarray(p.kill_step)
    assert kill.tolist() == [-1, -1, 9, 0, 0, 0]
    # laps before the crash are disarmed, the rest are armed
    assert np.asarray(p.active()).tolist() == [False, False] + [True] * 4


def test_storm_is_seed_deterministic(monkeypatch):
    a = faults_mod.storm(64, seed=7)
    b = faults_mod.storm(64, seed=7)
    np.testing.assert_array_equal(np.asarray(a.as_rows()),
                                  np.asarray(b.as_rows()))
    c = faults_mod.storm(64, seed=8)
    assert not np.array_equal(np.asarray(a.as_rows()),
                              np.asarray(c.as_rows()))
    # CI rotates the seed through the environment
    monkeypatch.setenv("FAULT_SEED", "12345")
    assert faults_mod.storm_seed() == 12345
    d = faults_mod.storm(64)
    e = faults_mod.storm(64, seed=12345)
    np.testing.assert_array_equal(np.asarray(d.as_rows()),
                                  np.asarray(e.as_rows()))


def test_pallas_supported_predicate():
    assert faults_mod.FaultPlan.kill_at(5).pallas_supported()
    assert faults_mod.FaultPlan.none().pallas_supported()
    assert not faults_mod.FaultPlan.suppress_at(5).pallas_supported()
    assert not faults_mod.FaultPlan.cas_fail_at(0).pallas_supported()
    assert not faults_mod.FaultPlan.enable_zero_at(0).pallas_supported()


# --- machine-level fault semantics -------------------------------------------

def _three_writes():
    """Plain WQ of three immediate writes, plus a fourth on a second WQ
    gated on the *last* producer's completion count (the
    completion-starvation probe: WAIT thresholds are monotonic counters,
    so only a shortfall in the total count starves it)."""
    p = assembler.Program(256)
    a, b, c, d = (p.word(0) for _ in range(4))
    wq = p.add_wq(4)
    wq.write_imm(dst=a, value=11)
    wq.write_imm(dst=b, value=22)
    r2 = wq.write_imm(dst=c, value=33)
    gated = p.add_wq(2)
    gated.wait_for(r2)
    gated.write_imm(dst=d, value=44)
    spec, st0 = p.finalize()
    return spec, st0, (a, b, c, d)


def test_kill_truncates_at_exact_step():
    # single WQ -> scheduling order == posting order, so kill_at(k)
    # means exactly the first k writes landed
    p = assembler.Program(256)
    words = [p.word(0) for _ in range(3)]
    wq = p.add_wq(4)
    for i, w in enumerate(words):
        wq.write_imm(dst=w, value=11 * (i + 1))
    spec, st0 = p.finalize()
    for k in range(4):
        out = machine.run(spec, st0, 16,
                          faults=faults_mod.FaultPlan.kill_at(k))
        mem = np.asarray(out.mem)
        want = [11 * (i + 1) if i < k else 0 for i in range(3)]
        assert [mem[w] for w in words] == want, k
        assert int(out.steps) == k


def test_suppress_drops_effect_and_completion():
    """The suppressed WR's write never lands, later WRs in the same WQ
    still run (head advances), but the WAIT on its completion starves."""
    spec, st0, (a, b, c, d) = _three_writes()
    out = machine.run(spec, st0, 16,
                      faults=faults_mod.FaultPlan.suppress_at(0))
    mem = np.asarray(out.mem)
    assert mem[a] == 0          # dropped WR: no effect
    assert mem[b] == 22 and mem[c] == 33
    # one completion short of the WAIT's threshold -> the gated WQ starves
    assert mem[d] == 0
    # clean run serves the gated write
    clean = np.asarray(machine.run(spec, st0, 16).mem)
    assert clean[d] == 44


def test_cas_fault_forces_compare_miss():
    p = assembler.Program(256)
    x = p.word(5)
    ret = p.word(0)
    wq = p.add_wq(2)
    wq.cas(dst=x, old=5, new=99, ret=ret)
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, 8,
                      faults=faults_mod.FaultPlan.cas_fail_at(0))
    mem = np.asarray(out.mem)
    assert mem[x] == 5          # the would-have-won CAS spuriously missed
    assert mem[ret] == 5        # return-old still reports the true value
    clean = np.asarray(machine.run(spec, st0, 8).mem)
    assert clean[x] == 99


def test_enable_zero_loses_the_doorbell():
    p = assembler.Program(256)
    d = p.word(0)
    gated = p.add_wq(2, managed=True, ordering=machine.isa.ORD_DOORBELL,
                     initial_enable=0)
    gated.write_imm(dst=d, value=7)
    ctl = p.add_wq(2)
    ctl.enable(gated, upto=1)
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, 8,
                      faults=faults_mod.FaultPlan.enable_zero_at(0))
    assert int(np.asarray(out.mem)[d]) == 0       # doorbell lost
    clean = machine.run(spec, st0, 8)
    assert int(np.asarray(clean.mem)[d]) == 7


def test_disarmed_plan_is_bit_exact_with_clean_run():
    spec, st0, _ = _three_writes()
    clean = machine.run(spec, st0, 16)
    armed_off = machine.run(spec, st0, 16,
                            faults=faults_mod.FaultPlan.none())
    for a, b in zip(jax.tree_util.tree_leaves(clean),
                    jax.tree_util.tree_leaves(armed_off)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- pallas backend parity ---------------------------------------------------

def _straight_line():
    p = assembler.Program(256)
    x = p.word(5)
    y = p.word(0)
    wq = p.add_wq(8)
    wq.read(src=x, dst=y)
    wq.add(dst=y, addend=10)
    wq.cas(dst=y, old=15, new=99)
    wq.max_(dst=y, operand=120)
    wq.min_(dst=y, operand=60)
    return p.finalize()


@pytest.mark.parametrize("k", [0, 2, 4, 99])
def test_pallas_kill_parity_bit_exact(k):
    spec, st0 = _straight_line()
    rows = faults_mod.FaultPlan.kill_at(k, shape=(3,))
    batch = jax.tree_util.tree_map(lambda a: jnp.stack([a] * 3), st0)
    out_i = ChainEngine.for_spec(spec).run_batch(batch, 16, rows)
    out_p = ChainEngine.for_spec(spec, "pallas-interpret").run_batch(
        batch, 16, rows)
    np.testing.assert_array_equal(np.asarray(out_i.mem),
                                  np.asarray(out_p.mem))
    np.testing.assert_array_equal(np.asarray(out_i.steps),
                                  np.asarray(out_p.steps))


def test_pallas_rejects_unsupported_fault_kinds():
    spec, st0 = _straight_line()
    eng = ChainEngine.for_spec(spec, "pallas-interpret")
    batch = jax.tree_util.tree_map(lambda a: jnp.stack([a]), st0)
    with pytest.raises(ValueError, match="suppress|truncation"):
        eng.run_batch(batch, 16, faults_mod.FaultPlan.suppress_at(
            1, shape=(1,)))


def test_pallas_rejects_traced_fault_params():
    spec, st0 = _straight_line()
    eng = ChainEngine.for_spec(spec, "pallas-interpret")
    batch = jax.tree_util.tree_map(lambda a: jnp.stack([a]), st0)

    with pytest.raises(ValueError):
        jax.jit(lambda f: eng.run_batch(batch, 16, f))(
            faults_mod.FaultPlan.kill_at(2, shape=(1,)))


# --- fsck: engineered violations --------------------------------------------

def _frame(n, v=2):
    return (jnp.zeros((1, n), jnp.int32), jnp.zeros((1, n, v), jnp.int32))


def test_fsck_clean_on_valid_frame():
    keys, vals = _frame(8)
    k = store.keys_homed_at(2, 1, 8)[0]
    keys = keys.at[0, 2].set(k)
    vals = vals.at[0, 2].set(jnp.asarray([5, 6]))
    rep = fsck.check_invariants(keys, vals, neighborhood=4)
    assert rep.clean and rep.repairable
    assert repr(rep) == "FsckReport(clean)"


def test_fsck_torn_claim_detected_and_repaired():
    keys, vals = _frame(8)
    k = store.keys_homed_at(2, 1, 8)[0]
    keys = keys.at[0, 2].set(k)           # live key, all-zero value row
    rep = fsck.check_invariants(keys, vals, neighborhood=4)
    assert [v.kind for v in rep.violations] == ["torn-claim"]
    assert rep.repairable
    keys, vals, actions = fsck.repair(keys, vals, rep, neighborhood=4)
    assert [a.action for a in actions] == ["vacate"]
    assert int(keys[0, 2]) == hopscotch.EMPTY
    assert fsck.check_invariants(keys, vals, neighborhood=4).clean


def test_fsck_stale_row_detected_and_zeroed():
    keys, vals = _frame(8)
    vals = vals.at[0, 5].set(jnp.asarray([9, 9]))   # EMPTY bucket, ghost row
    rep = fsck.check_invariants(keys, vals, neighborhood=4)
    assert [v.kind for v in rep.violations] == ["stale-row"]
    keys, vals, actions = fsck.repair(keys, vals, rep, neighborhood=4)
    assert [a.action for a in actions] == ["zero-row"]
    assert not np.asarray(vals[0, 5]).any()
    assert fsck.check_invariants(keys, vals, neighborhood=4).clean


def test_fsck_dup_key_vacates_copy_farthest_from_home():
    keys, vals = _frame(8)
    k = store.keys_homed_at(2, 1, 8)[0]
    # a half-done move: the original at home, the copy one bucket out
    keys = keys.at[0, 2].set(k).at[0, 3].set(k)
    vals = vals.at[0, 2].set(jnp.asarray([5, 6]))
    vals = vals.at[0, 3].set(jnp.asarray([5, 6]))
    rep = fsck.check_invariants(keys, vals, neighborhood=4)
    assert [v.kind for v in rep.violations] == ["dup-key"]
    keys, vals, _ = fsck.repair(keys, vals, rep, neighborhood=4)
    # rollback keeps the copy closest to home (the pre-move original)
    assert int(keys[0, 2]) == k and int(keys[0, 3]) == hopscotch.EMPTY
    assert fsck.check_invariants(keys, vals, neighborhood=4).clean


def test_fsck_neighborhood_breach_reported_not_repaired():
    keys, vals = _frame(8)
    k = store.keys_homed_at(0, 1, 8)[0]
    keys = keys.at[0, 5].set(k)           # 5 buckets from home, H=4
    vals = vals.at[0, 5].set(jnp.asarray([1, 1]))
    rep = fsck.check_invariants(keys, vals, neighborhood=4)
    assert [v.kind for v in rep.violations] == ["neighborhood"]
    assert not rep.repairable             # a chain bug, not a crash
    keys2, vals2, actions = fsck.repair(keys, vals, rep, neighborhood=4)
    assert not actions
    np.testing.assert_array_equal(np.asarray(keys2), np.asarray(keys))


def _resize_state(n=8, v=2):
    ok, ov = _frame(n, v)
    gk, gv = _frame(2 * n, v)
    return ok, ov, gk, gv


def test_fsck_watermark_resident_reported():
    ok, ov, gk, gv = _resize_state()
    k = store.keys_homed_at(1, 1, 8)[0]
    ok = ok.at[0, 1].set(k)
    ov = ov.at[0, 1].set(jnp.asarray([3, 3]))
    rs = store.ResizeState(ok, ov, gk, gv, jnp.asarray([4], jnp.int32))
    rep = fsck.check_invariants(resize=rs, neighborhood=4)
    kinds = [v.kind for v in rep.violations]
    assert "watermark" in kinds and not rep.repairable


@pytest.mark.parametrize("new_row_complete", [True, False])
def test_fsck_cross_frame_dup_policy(new_row_complete):
    """Complete new copy -> old loses (finish the lost vacate); torn new
    claim (zero row) -> the claim is vacated and the lap re-migrates."""
    ok, ov, gk, gv = _resize_state()
    k = store.keys_homed_at(2, 1, 8)[0]
    ok = ok.at[0, 2].set(k)
    ov = ov.at[0, 2].set(jnp.asarray([7, 8]))
    b_new = int(hopscotch.bucket_of(k, 16))
    gk = gk.at[0, b_new].set(k)
    if new_row_complete:
        gv = gv.at[0, b_new].set(jnp.asarray([7, 8]))
    rs = store.ResizeState(ok, ov, gk, gv, jnp.zeros((1,), jnp.int32))
    rep = fsck.check_invariants(resize=rs, neighborhood=4)
    assert rep.of_kind("cross-frame-dup") and rep.repairable
    rs2, actions = fsck.repair_resize(rs, rep, neighborhood=4)
    acts = {a.action for a in actions}
    if new_row_complete:
        assert "vacate-old" in acts
        assert int(rs2.keys[0, 2]) == hopscotch.EMPTY
        assert int(rs2.new_keys[0, b_new]) == k
    else:
        assert "vacate-new" in acts
        assert int(rs2.keys[0, 2]) == k          # old copy intact
        assert int(rs2.new_keys[0, b_new]) == hopscotch.EMPTY
    assert fsck.check_invariants(resize=rs2, neighborhood=4).clean


# --- cut-point sweeps: kill every step, repair, converge to the oracle -------

def _writer_scenario():
    """n=16, H=4: a fresh insert into a half-full neighborhood."""
    n, v, h = 16, 2, 4
    w = programs.build_hopscotch_writer(n, v, neighborhood=h)
    homed = store.keys_homed_at(3, 3, n)
    keys0 = np.zeros(n, np.int32)
    vals0 = np.zeros((n, v), np.int32)
    for b, k in zip((3, 4), homed[:2]):
        keys0[b] = k
        vals0[b] = [k & 0xFF, b]
    q, qval = homed[2], [77, 78]
    return w, h, keys0, vals0, q, qval


def _displacer_scenario():
    """n=16, H=4: neighborhood [3..6] full, bucket 6's resident is homed
    at 6 (movable to 7) — the clean outcome is one bubble move and a
    SET_DISPLACED claim."""
    n, v, h = 16, 2, 4
    d = programs.build_hopscotch_displacer(n, v, neighborhood=h,
                                           max_search=16, max_moves=8)
    homed3 = store.keys_homed_at(3, 4, n)
    homed6 = store.keys_homed_at(6, 1, n)
    keys0 = np.zeros(n, np.int32)
    vals0 = np.zeros((n, v), np.int32)
    for b, k in zip((3, 4, 5), homed3[:3]):
        keys0[b] = k
        vals0[b] = [k & 0xFF, b]
    keys0[6] = homed6[0]
    vals0[6] = [homed6[0] & 0xFF, 6]
    q, qval = homed3[3], [91, 92]
    return d, h, keys0, vals0, q, qval


def _sweep_writer_like(prog, h, keys0, vals0, q, qval, cuts,
                       max_search=16, max_moves=8):
    """Kill a SET chain at each cut, then fsck + repair + (re-issue if
    non-terminal) and demand bit-exact convergence with the host oracle.
    Returns the number of cuts that produced a repairable torn state."""
    oracle = hopscotch.HopscotchTable(keys0.copy(), vals0.copy(), h)
    ost = hopscotch.insert_many_displaced(
        oracle, [q], [np.asarray(qval)], max_search=max_search,
        max_moves=max_moves)
    assert int(ost[0]) in TERMINAL_SET

    payload = prog.device_payloads(
        jnp.asarray([q]), jnp.asarray([hopscotch.bucket_of(q, len(keys0))]),
        jnp.asarray([qval]))[0]
    fuel = prog.fuel
    faulted = jax.jit(prog.run_one_faulted, static_argnames=("max_steps",))
    clean = jax.jit(prog.run_one, static_argnames=("max_steps",))
    k0, v0 = jnp.asarray(keys0), jnp.asarray(vals0)

    torn_seen = 0
    for cut in cuts:
        plan = faults_mod.FaultPlan.kill_at(jnp.int32(cut))
        st1, tk, tv = faulted(k0, v0, payload, max_steps=fuel, faults=plan)
        tk, tv = tk[None], tv[None]
        rep = fsck.check_invariants(tk, tv, neighborhood=h)
        assert rep.repairable, (cut, rep)
        if not rep.clean:
            torn_seen += 1
            tk, tv, _ = fsck.repair(tk, tv, rep, neighborhood=h)
            assert fsck.check_invariants(tk, tv, neighborhood=h).clean
        rk, rv = tk[0], tv[0]
        # unconditional re-issue: for a chain that already finished the
        # re-issue is an idempotent same-value update, and for a torn one
        # it is the roll-forward — statuses alone can't distinguish them
        # (a response WR may land before the chain's tail effects)
        st2, rk, rv = clean(rk, rv, payload, max_steps=fuel)
        del st1
        assert int(st2) in TERMINAL_SET, (cut, int(st2))
        np.testing.assert_array_equal(np.asarray(rk), oracle.keys,
                                      err_msg=f"cut={cut}")
        np.testing.assert_array_equal(np.asarray(rv), oracle.values,
                                      err_msg=f"cut={cut}")
    return torn_seen


def test_writer_cutpoint_sweep_smoke():
    w, h, keys0, vals0, q, qval = _writer_scenario()
    cuts = sorted(set(list(range(0, w.fuel + 1, 7)) + [w.fuel]))
    _sweep_writer_like(w, h, keys0, vals0, q, qval, cuts)


@pytest.mark.slow
def test_writer_cutpoint_sweep_full():
    w, h, keys0, vals0, q, qval = _writer_scenario()
    _sweep_writer_like(w, h, keys0, vals0, q, qval, range(w.fuel + 1))


def test_displacer_cutpoint_sweep_smoke():
    d, h, keys0, vals0, q, qval = _displacer_scenario()
    # every 37th step plus the known-delicate region around the bubble
    cuts = sorted(set(list(range(0, d.fuel + 1, 37))
                      + list(range(180, 200)) + [d.fuel]))
    torn = _sweep_writer_like(d, h, keys0, vals0, q, qval, cuts)
    assert torn > 0        # the sweep must actually cross torn states


@pytest.mark.slow
def test_displacer_cutpoint_sweep_full():
    d, h, keys0, vals0, q, qval = _displacer_scenario()
    torn = _sweep_writer_like(d, h, keys0, vals0, q, qval,
                              range(d.fuel + 1))
    assert torn > 0


def _migrator_scenario():
    """n=8 -> 2n=16, H=4: residents at old buckets 2 and 5; the swept
    lap migrates bucket 2."""
    n, v, h = 8, 2, 4
    m = programs.build_hopscotch_migrator(n, v, neighborhood=h)
    k2 = store.keys_homed_at(2, 1, n)[0]
    k5 = store.keys_homed_at(5, 1, n)[0]
    ok0 = np.zeros(n, np.int32)
    ov0 = np.zeros((n, v), np.int32)
    ok0[2], ov0[2] = k2, [21, 22]
    ok0[5], ov0[5] = k5, [51, 52]
    return m, h, ok0, ov0


def _sweep_migrator(cuts):
    m, h, ok0, ov0 = _migrator_scenario()
    n = len(ok0)

    to = hopscotch.HopscotchTable(ok0.copy(), ov0.copy(), h)
    tn = hopscotch.make_table(2 * n, ov0.shape[1], h)
    assert to.migrate_bucket(tn, 2) == programs.MIG_MOVED

    nk0 = jnp.zeros((2 * n,), jnp.int32)
    nv0 = jnp.zeros((2 * n, ov0.shape[1]), jnp.int32)
    fuel = m.fuel
    faulted = jax.jit(m.run_one_faulted, static_argnames=("max_steps",))
    clean = jax.jit(m.run_one, static_argnames=("max_steps",))
    ok0j, ov0j = jnp.asarray(ok0), jnp.asarray(ov0)
    pay0 = m.device_payloads(jnp.asarray([2]), ok0j)[0]

    torn_seen = 0
    for cut in cuts:
        plan = faults_mod.FaultPlan.kill_at(jnp.int32(cut))
        st1, ok, ov, nk, nv = faulted(ok0j, ov0j, nk0, nv0, pay0,
                                      max_steps=fuel, faults=plan)
        rs = store.ResizeState(ok[None], ov[None], nk[None], nv[None],
                               jnp.zeros((1,), jnp.int32))
        rep = fsck.check_invariants(resize=rs, neighborhood=h)
        assert rep.repairable, (cut, rep)
        if not rep.clean:
            torn_seen += 1
            rs, _ = fsck.repair_resize(rs, rep, neighborhood=h)
            assert fsck.check_invariants(resize=rs, neighborhood=h).clean
        rok, rov = rs.keys[0], rs.vals[0]
        rnk, rnv = rs.new_keys[0], rs.new_vals[0]
        # Recovery re-drives while the source bucket is still live — NOT
        # while the status is non-terminal: the lap's MIG_MOVED response
        # lands before the copy/vacate tail, so a terminal status can
        # coexist with an unfinished move (a posted completion is not an
        # applied state — the exact claim under test).  A source bucket
        # the repair already drained means the lap is complete.
        if int(np.asarray(rok)[2]) != hopscotch.EMPTY:
            pay = m.device_payloads(jnp.asarray([2]), rok)[0]
            st2, rok, rov, rnk, rnv = clean(rok, rov, rnk, rnv, pay,
                                            max_steps=fuel)
            assert int(st2) in TERMINAL_MIG, (cut, int(st2))
        np.testing.assert_array_equal(np.asarray(rok), to.keys,
                                      err_msg=f"cut={cut}")
        np.testing.assert_array_equal(np.asarray(rov), to.values,
                                      err_msg=f"cut={cut}")
        np.testing.assert_array_equal(np.asarray(rnk), tn.keys,
                                      err_msg=f"cut={cut}")
        np.testing.assert_array_equal(np.asarray(rnv), tn.values,
                                      err_msg=f"cut={cut}")
    return torn_seen


def test_migration_lap_cutpoint_sweep_smoke():
    m, *_ = _migrator_scenario()
    cuts = sorted(set(list(range(0, m.fuel + 1, 5)) + [m.fuel]))
    _sweep_migrator(cuts)


@pytest.mark.slow
def test_migration_lap_cutpoint_sweep_full():
    m, *_ = _migrator_scenario()
    torn = _sweep_migrator(range(m.fuel + 1))
    assert torn > 0


# --- faulted sharded paths ---------------------------------------------------

def test_sharded_set_disarmed_plan_bit_exact():
    """An all-disarmed FaultPlan must not perturb the sharded SET path:
    the storm benchmark's un-hit requests ride the faulted variant."""
    mesh = _one_shard_mesh()
    keys, vals = _frame(32)
    sk = jnp.asarray([[0x101, 0x202, 0x303, 0x404]], jnp.int32)
    sv = jnp.arange(8, dtype=jnp.int32).reshape(1, 4, 2) + 1
    res_c, kc, vc = store.sharded_set(mesh, "kv", keys, vals, sk, sv,
                                      neighborhood=4)
    res_f, kf, vf = store.sharded_set(
        mesh, "kv", keys, vals, sk, sv, neighborhood=4,
        faults=faults_mod.FaultPlan.none(sk.shape))
    np.testing.assert_array_equal(np.asarray(res_c.status),
                                  np.asarray(res_f.status))
    np.testing.assert_array_equal(np.asarray(kc), np.asarray(kf))
    np.testing.assert_array_equal(np.asarray(vc), np.asarray(vf))


def test_sharded_set_armed_row_never_escalates():
    """A faulted request must not paper over its crash by escalating to
    the displacer: the armed row keeps the writer's non-terminal answer
    where the clean run displaces."""
    mesh = _one_shard_mesh()
    _, h, keys0, vals0, q, qval = _displacer_scenario()
    keys, vals = jnp.asarray(keys0)[None], jnp.asarray(vals0)[None]
    sk = jnp.asarray([[q]], jnp.int32)
    sv = jnp.asarray([[qval]], jnp.int32)
    res_c, kc, _ = store.sharded_set(mesh, "kv", keys, vals, sk, sv,
                                     neighborhood=h)
    assert int(np.asarray(res_c.status)[0, 0]) == programs.SET_DISPLACED
    # armed but never firing (kill far beyond the chain's fuel): the row
    # still must not enter the displacer stage
    plan = faults_mod.FaultPlan.kill_at(30_000, shape=sk.shape)
    res_f, kf, _ = store.sharded_set(mesh, "kv", keys, vals, sk, sv,
                                     neighborhood=h, faults=plan)
    assert (int(np.asarray(res_f.status)[0, 0])
            == programs.SET_NEEDS_DISPLACEMENT)
    np.testing.assert_array_equal(np.asarray(kf), np.asarray(keys))


def test_sharded_set_storm_recovers_every_request():
    """Batch path under a seeded storm: faulted rows are audited,
    repaired and re-issued; afterwards every key serves its value and
    the store is fsck-clean."""
    mesh = _one_shard_mesh()
    h = 4
    keys, vals = _frame(32)
    n_req = 12
    sk = np.arange(1, n_req + 1, dtype=np.int32)[None, :] * 17
    sv = np.stack([sk[0] % 251 + 1, sk[0] % 97 + 1], axis=1)[None]
    plan = faults_mod.FaultPlan(*[leaf[None] for leaf in faults_mod.storm(
        n_req, p_fault=0.5, max_step=60, seed=20260807)])
    res, keys, vals = store.sharded_set(mesh, "kv", keys, vals,
                                        jnp.asarray(sk), jnp.asarray(sv),
                                        neighborhood=h, faults=plan)
    rep = fsck.check_invariants(keys, vals, neighborhood=h)
    assert rep.repairable
    if not rep.clean:
        keys, vals, _ = fsck.repair(keys, vals, rep, neighborhood=h)
    retry = ~np.isin(np.asarray(res.status), TERMINAL_SET)
    assert retry.any()          # the storm must actually interrupt chains
    res2, keys, vals = store.sharded_set(
        mesh, "kv", keys, vals, jnp.asarray(sk), jnp.asarray(sv),
        neighborhood=h, live=jnp.asarray(retry))
    st2 = np.asarray(res2.status)[retry]
    assert np.isin(st2, TERMINAL_SET).all()
    found, got = hopscotch.lookup(keys[0], vals[0], jnp.asarray(sk[0]), h)
    assert np.asarray(found).all()
    np.testing.assert_array_equal(np.asarray(got), sv[0])
    assert fsck.check_invariants(keys, vals, neighborhood=h).clean


def test_sharded_resize_fault_parks_watermark_then_recovers():
    """A shard dying at lap j of a resize quantum parks the watermark on
    that lap's bucket; fsck + repair + re-driven quanta still converge,
    and the finished table serves every key."""
    mesh = _one_shard_mesh()
    n, h = 8, 4
    k2 = store.keys_homed_at(2, 1, n)[0]
    k5 = store.keys_homed_at(5, 1, n)[0]
    keys = jnp.zeros((1, n), jnp.int32).at[0, 2].set(k2).at[0, 5].set(k5)
    vals = jnp.zeros((1, n, 2), jnp.int32)
    vals = vals.at[0, 2].set(jnp.asarray([21, 22]))
    vals = vals.at[0, 5].set(jnp.asarray([51, 52]))
    rs = store.begin_resize(keys, vals)
    plan = faults_mod.FaultPlan(*[leaf[None] for leaf in
                                  faults_mod.FaultPlan.kill_lap(
                                      n, lap=2, step=30)])
    rs, report = store.sharded_resize(mesh, "kv", rs, step=n,
                                      neighborhood=h, faults=plan)
    # buckets 0,1 are EMPTY laps (drained for free); the fired lap at
    # bucket 2 parks the watermark there
    assert int(np.asarray(rs.watermark)[0]) == 2
    assert int(np.asarray(report.stuck)[0]) == 0
    rep = fsck.check_invariants(resize=rs, neighborhood=h)
    assert rep.repairable
    if not rep.clean:
        rs, _ = fsck.repair_resize(rs, rep, neighborhood=h)
        assert fsck.check_invariants(resize=rs, neighborhood=h).clean
    while not store.resize_done(rs):
        rs, _ = store.sharded_resize(mesh, "kv", rs, step=n,
                                     neighborhood=h)
    fk, fv = store.finish_resize(rs)
    found, got = hopscotch.lookup(fk[0], fv[0],
                                  jnp.asarray([k2, k5]), h)
    assert np.asarray(found).all()
    np.testing.assert_array_equal(np.asarray(got), [[21, 22], [51, 52]])


# --- service-level recovery --------------------------------------------------

def _service(items=None, **kw):
    items = items if items is not None else [(k, [k * 2, k * 2 + 1])
                                             for k in range(1, 7)]
    return failure.ShardedKVService.start(items, n_shards=1,
                                          buckets_per_shard=64,
                                          val_words=2, **kw)


@pytest.mark.parametrize("plan,must_retry", [
    (faults_mod.FaultPlan.kill_at(10), True),
    (faults_mod.FaultPlan.suppress_at(5), True),
    (faults_mod.FaultPlan.cas_fail_at(0), False),
    (faults_mod.FaultPlan.enable_zero_at(0), True),
])
def test_set_reliable_recovers_from_each_fault_kind(plan, must_retry):
    svc = _service()
    key, value = 0x1234, [7, 8]
    status, attempts = svc.set_reliable(key, value, faults=plan)
    assert status in TERMINAL_SET
    assert attempts <= svc.retry_budget + 1
    if must_retry:
        # the fault genuinely interrupted attempt 1
        assert attempts >= 2
    res = svc.get_many([key])
    assert bool(np.asarray(res.found)[0, 0])
    np.testing.assert_array_equal(np.asarray(res.values)[0, 0], value)
    assert svc.fsck_and_repair().clean


def test_set_reliable_clean_path_is_one_attempt():
    svc = _service()
    status, attempts = svc.set_reliable(0x4321, [9, 9])
    assert status in TERMINAL_SET and attempts == 1
    assert svc.repairs_applied == 0


def test_chain_interrupted_raised_with_clean_store():
    """Budget exhausted on a genuinely unplaceable key (full immovable
    neighborhood, growth disabled): the typed error reports the key and
    attempt count, and the failed retries left the store fsck-clean."""
    homed = store.keys_homed_at(0, 9, 16)
    svc = failure.ShardedKVService.start(
        [(k, [k & 0xFF, 1]) for k in homed[:8]],
        n_shards=1, buckets_per_shard=16, val_words=2)
    svc.auto_resize = False
    svc.retry_budget = 1
    with pytest.raises(failure.ChainInterrupted) as ei:
        svc.set_reliable(homed[8], [2, 3])
    err = ei.value
    assert err.key == homed[8]
    assert err.attempts == svc.retry_budget + 1
    assert err.fsck_clean
    assert f"{homed[8]:#x}" in str(err)
    # the store survived the failed attempts untouched
    found, _ = hopscotch.lookup(svc.keys[0], svc.vals[0],
                                jnp.asarray(homed[:8]), 8)
    assert np.asarray(found).all()


def test_resize_stuck_is_typed_with_parked_bucket():
    """ResizeStuck carries the parked (shard, bucket) pairs and stays a
    RuntimeError for back-compat.  (It now only fires when a *chained*
    growth dead-ends too — a no-progress quantum on the doubled frame
    escalates into a second growth instead; see the test below.)"""
    err = store.ResizeStuck([0], [3])
    assert isinstance(err, RuntimeError)      # back-compat for callers
    assert err.stuck == [(0, 3)]
    assert "shard 0 bucket 3" in str(err)


def test_resize_dead_end_chains_second_growth():
    """PR 5's nuance, closed: a resident unplaceable even in the doubled
    frame no longer raises — the doubled frame itself grows (2n -> 4n,
    drained by the migrator chains) and the parked resident lands there
    through the writer chain.  Every key survives."""
    n = 8
    k0 = store.keys_homed_at(0, 1, n)[0]
    svc = failure.ShardedKVService.start([(k0, [5, 5])], n_shards=1,
                                         buckets_per_shard=n, val_words=2)
    # hand-craft the doubled frame completely full: the migrating
    # resident has nowhere to go, even displaced
    nk = np.zeros((1, 2 * n), np.int32)
    nv = np.zeros((1, 2 * n, 2), np.int32)
    for b in range(2 * n):
        # start past the resident's key range so no filler aliases the
        # migrating key (a match would discard the lap, not park it)
        nk[0, b] = store.keys_homed_at(b, 1, 2 * n, start=0x1000)[0]
        nv[0, b] = [b + 1, 1]
    svc.resize = store.ResizeState(
        jnp.asarray(svc.keys), jnp.asarray(svc.vals),
        jnp.asarray(nk), jnp.asarray(nv), jnp.zeros((1,), jnp.int32))
    svc.crash_host()                          # §5.6: chains only
    svc._advance_resize()
    assert svc.resize is None
    assert svc.chained_growths == 1
    assert svc.keys.shape[1] == 4 * n         # quadrupled frame adopted
    all_keys = [k0] + [int(k) for k in nk[0]]
    res = svc.get_many(np.asarray([all_keys], np.int32))
    assert np.asarray(res.found).all()


# --- satellite: readable statuses and results --------------------------------

def test_status_names_cover_every_code():
    for code, name in [(programs.SET_UPDATED, "SET_UPDATED"),
                       (programs.SET_INSERTED, "SET_INSERTED"),
                       (programs.SET_NEEDS_DISPLACEMENT,
                        "SET_NEEDS_DISPLACEMENT"),
                       (programs.SET_DISPLACED, "SET_DISPLACED"),
                       (programs.SET_NEEDS_RESIZE, "SET_NEEDS_RESIZE"),
                       (programs.MIG_MOVED, "MIG_MOVED"),
                       (programs.MIG_DISCARDED, "MIG_DISCARDED"),
                       (programs.MIG_NEEDS_DISPLACE, "MIG_NEEDS_DISPLACE"),
                       (0, "UNSERVED")]:
        assert hopscotch.STATUS_NAMES[code] == name
        assert hopscotch.status_name(code) == name
    assert hopscotch.status_name(99) == "status<99>"


def test_set_result_repr_is_a_status_histogram():
    res = store.SetResult(
        status=jnp.asarray([[1, 2, 2, 5]], jnp.int32),
        applied=jnp.asarray([[True, True, True, False]]),
        ok=jnp.asarray([[True, True, True, True]]),
        dropped=jnp.zeros((1,), jnp.int32),
        deferred=jnp.zeros((1,), jnp.int32))
    r = repr(res)
    assert "SET_UPDATED=1" in r and "SET_INSERTED=2" in r
    assert "SET_NEEDS_RESIZE=1" in r and "ok 4/4" in r


def test_get_result_repr_summarizes():
    res = store.GetResult(
        found=jnp.asarray([[True, False]]),
        values=jnp.zeros((1, 2, 2), jnp.int32),
        ok=jnp.asarray([[True, True]]),
        dropped=jnp.zeros((1,), jnp.int32),
        deferred=jnp.zeros((1,), jnp.int32))
    assert "found 1/2" in repr(res) and "ok 2/2" in repr(res)


# --- concurrent writers: exhaustive 2-writer interleaving sweep --------------
#
# The linearizability claim behind `ChainEngine.run_interleaved` and the
# store's `n_writers>1` path: because each insert's only cross-chain
# conflict is ONE CAS claim (and CAS executes atomically at the NIC), any
# interleaving of two racing writer chains commits the same table as SOME
# serialized order of the two requests.  The sweep proves it by brute
# force: for every cut point c, run writer A for its first c completions,
# then let writer B (and then A's remainder) run to quiescence, and demand
# the shared image lands bit-exactly on one of the two sequential oracles
# — fsck-clean, both statuses terminal, zero divergent schedules.

def _mw_scenario():
    """n=16, H=4: two distinct keys homed at the same bucket, racing for
    the two free slots of a half-full neighborhood."""
    n, v, h = 16, 2, 4
    group = programs.build_multi_writer_group(n, v, neighborhood=h,
                                              n_writers=2)
    homed = store.keys_homed_at(3, 4, n)
    keys0 = np.zeros(n, np.int32)
    vals0 = np.zeros((n, v), np.int32)
    for b, k in zip((3, 4), homed[:2]):
        keys0[b] = k
        vals0[b] = [k & 0xFF, b]
    qa, qb = homed[2], homed[3]
    return group, h, keys0, vals0, qa, qb


def _mw_oracles(h, keys0, vals0, qa, qb):
    """The two sequential single-writer outcomes (AB and BA order)."""
    n = len(keys0)
    w = programs.build_hopscotch_writer(n, len(vals0[0]), neighborhood=h)
    run = jax.jit(w.run_one, static_argnames=("max_steps",))
    outs = {}
    for name, order in (("AB", (qa, qb)), ("BA", (qb, qa))):
        k, v = jnp.asarray(keys0), jnp.asarray(vals0)
        for q in order:
            pay = w.device_payloads(
                jnp.asarray([q]),
                jnp.asarray([hopscotch.bucket_of(q, n)]),
                jnp.asarray([[q & 0xFF, q >> 4]]))[0]
            st, k, v = run(k, v, pay, max_steps=w.fuel)
            assert int(st) in TERMINAL_SET
        outs[name] = (np.asarray(k), np.asarray(v))
    return outs


def _sweep_mw(cuts):
    group, h, keys0, vals0, qa, qb = _mw_scenario()
    oracles = _mw_oracles(h, keys0, vals0, qa, qb)
    n = len(keys0)
    pay = group.device_payloads(
        jnp.asarray([qa, qb]),
        jnp.asarray([hopscotch.bucket_of(q, n) for q in (qa, qb)]),
        jnp.asarray([[qa & 0xFF, qa >> 4], [qb & 0xFF, qb >> 4]]))
    k0, v0 = jnp.asarray(keys0), jnp.asarray(vals0)
    diverged = []
    for cut in cuts:
        sched = machine.Schedule.cut(jnp.int32(cut))
        st, k, v = group.run_group(k0, v0, pay, sched, group.fuel)
        st, k, v = np.asarray(st), np.asarray(k), np.asarray(v)
        assert all(int(s) in TERMINAL_SET for s in st), (cut, st)
        rep = fsck.check_invariants(k[None], v[None], neighborhood=h)
        assert rep.clean, (cut, rep)
        hit = any((k == ok).all() and (v == ov).all()
                  for ok, ov in oracles.values())
        if not hit:
            diverged.append(cut)
    assert diverged == [], f"non-linearizable cuts: {diverged}"


def test_multiwriter_cutpoint_sweep_smoke():
    group, *_ = _mw_scenario()
    fuel = group.writer_fuel
    _sweep_mw(sorted(set(list(range(0, fuel + 1, 7)) + [fuel])))


@pytest.mark.slow
def test_multiwriter_cutpoint_sweep_full():
    group, *_ = _mw_scenario()
    _sweep_mw(range(group.writer_fuel + 1))


def test_multiwriter_serialized_schedule_matches_sequential_oracle():
    """Schedule.serialized((0, 1)) must reproduce the AB oracle exactly —
    the concurrent engine's degenerate case IS the sequential engine."""
    group, h, keys0, vals0, qa, qb = _mw_scenario()
    oracles = _mw_oracles(h, keys0, vals0, qa, qb)
    n = len(keys0)
    pay = group.device_payloads(
        jnp.asarray([qa, qb]),
        jnp.asarray([hopscotch.bucket_of(q, n) for q in (qa, qb)]),
        jnp.asarray([[qa & 0xFF, qa >> 4], [qb & 0xFF, qb >> 4]]))
    k0, v0 = jnp.asarray(keys0), jnp.asarray(vals0)
    for name, order in (("AB", (0, 1)), ("BA", (1, 0))):
        sched = machine.Schedule.serialized(2, order=order)
        st, k, v = group.run_group(k0, v0, pay, sched, group.fuel)
        ok, ov = oracles[name]
        np.testing.assert_array_equal(np.asarray(k), ok, err_msg=name)
        np.testing.assert_array_equal(np.asarray(v), ov, err_msg=name)
