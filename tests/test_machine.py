"""Unit tests for the RedN chain VM: verbs, ordering, self-modification."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import assembler, constructs, cost, isa, machine


def run_prog(prog, max_steps=512, before=None):
    spec, state = prog.finalize()
    if before is not None:
        state = before(state)
    out = machine.run(spec, state, max_steps)
    return spec, out


def test_write_imm_and_copy():
    p = assembler.Program(512)
    a = p.alloc(4, [11, 22, 33, 44])
    b = p.alloc(4)
    wq = p.add_wq(4)
    wq.write_imm(dst=b, value=7)
    wq.write(src=a, dst=b + 1, ln=3)
    _, out = run_prog(p)
    got = np.asarray(out.mem[b:b + 4])
    assert got.tolist() == [7, 11, 22, 33]
    assert int(out.steps) == 2


def test_read_and_atomics():
    p = assembler.Program(512)
    x = p.word(5)
    y = p.word(0)
    wq = p.add_wq(8)
    wq.read(src=x, dst=y)                    # y = 5
    wq.add(dst=y, addend=10)                 # y = 15
    wq.max_(dst=y, operand=100)              # y = 100
    wq.min_(dst=y, operand=64)               # y = 64
    wq.cas(dst=y, old=64, new=1)             # y = 1
    wq.cas(dst=y, old=64, new=2)             # fails, y = 1
    _, out = run_prog(p)
    assert int(out.mem[y]) == 1


def test_cas_returns_old_value():
    p = assembler.Program(512)
    x = p.word(42)
    ret = p.word(0)
    wq = p.add_wq(2)
    wq.cas(dst=x, old=42, new=9, ret=ret)
    _, out = run_prog(p)
    assert int(out.mem[x]) == 9 and int(out.mem[ret]) == 42


def test_wait_blocks_until_completion():
    """WQ1 waits for 2 completions on WQ0 before writing."""
    p = assembler.Program(512)
    flag = p.word(0)
    wq0 = p.add_wq(4)
    wq1 = p.add_wq(4)
    wq1.wait(wq0, 2)
    wq1.write_imm(dst=flag, value=1)
    wq0.noop()
    wq0.noop()
    _, out = run_prog(p)
    assert int(out.mem[flag]) == 1
    # WAIT synchronizes the waiter's clock with the producer's completion
    assert float(out.clock[1]) >= float(out.last_comp_time[0]) - 1e-6


def test_wait_never_satisfied_quiesces():
    p = assembler.Program(512)
    flag = p.word(0)
    wq0 = p.add_wq(4)
    wq1 = p.add_wq(4)
    wq1.wait(wq0, 5)           # wq0 only ever completes 1
    wq1.write_imm(dst=flag, value=1)
    wq0.noop()
    _, out = run_prog(p, max_steps=100)
    assert int(out.mem[flag]) == 0
    assert int(out.steps) < 100  # quiesced, not fuel-exhausted


def test_suppressed_completion_starves_wait():
    """The `break` primitive: a WR with SUPPRESS_COMPLETION doesn't count."""
    p = assembler.Program(512)
    flag = p.word(0)
    wq0 = p.add_wq(4)
    wq1 = p.add_wq(4)
    wq1.wait(wq0, 2)
    wq1.write_imm(dst=flag, value=1)
    wq0.noop()
    wq0.noop(signaled=False)
    _, out = run_prog(p)
    assert int(out.mem[flag]) == 0


def test_managed_wq_needs_enable():
    p = assembler.Program(512)
    flag = p.word(0)
    m = p.add_wq(4, managed=True, ordering=isa.ORD_DOORBELL)
    m.write_imm(dst=flag, value=1)
    _, out = run_prog(p)
    assert int(out.mem[flag]) == 0        # never enabled

    p2 = assembler.Program(512)
    flag2 = p2.word(0)
    m2 = p2.add_wq(4, managed=True, ordering=isa.ORD_DOORBELL)
    ctl = p2.add_wq(4)
    m2.write_imm(dst=flag2, value=1)
    ctl.enable(m2, upto=1)
    _, out2 = run_prog(p2)
    assert int(out2.mem[flag2]) == 1


def test_self_modifying_write_rewrites_opcode():
    """A WRITE that edits a later WR's control word (the §3.2 primitive)."""
    p = assembler.Program(512)
    flag = p.word(0)
    new_ctrl = p.word(isa.pack_ctrl(isa.WRITE_IMM, 0))
    mod = p.add_wq(4, managed=True, ordering=isa.ORD_DOORBELL)
    ctl = p.add_wq(4)
    target = mod.post(isa.NOOP, dst=flag, opa=99)   # latent WRITE_IMM 99
    ctl.write(src=new_ctrl, dst=target.ctrl_addr, ln=1)
    ctl.enable(mod, upto=1)
    _, out = run_prog(p)
    assert int(out.mem[flag]) == 99


def test_send_recv_scatter():
    """Client SEND triggers a pre-posted RECV that scatters the payload."""
    p = assembler.Program(512)
    a = p.word(0)
    b = p.word(0)
    tbl = p.scatter_table([a, b])
    wq = p.add_wq(4)
    wq.recv(scatter_table=tbl)
    spec, state = p.finalize()
    state = machine.deliver(state, 0, [123, 456])
    out = machine.run(spec, state, 64)
    assert int(out.mem[a]) == 123 and int(out.mem[b]) == 456


def test_send_to_peer_qp():
    p = assembler.Program(512)
    payload = p.alloc(2, [7, 8])
    a = p.word(0)
    b = p.word(0)
    tbl = p.scatter_table([a, b])
    wq0 = p.add_wq(4)
    wq1 = p.add_wq(4)
    wq0.send(src=payload, ln=2, target_qp=1)
    wq1.recv(scatter_table=tbl)
    _, out = run_prog(p)
    assert int(out.mem[a]) == 7 and int(out.mem[b]) == 8


def test_response_send_to_client_region():
    p = assembler.Program(512)
    val = p.word(31337)
    resp = p.word(0)
    wq = p.add_wq(2)
    wq.send(src=val, ln=1, dst_region=resp, target_qp=-1)
    _, out = run_prog(p)
    assert int(out.mem[resp]) == 31337
    assert int(out.responses) == 1


def test_halt_pseudo_verb():
    p = assembler.Program(512)
    wq = p.add_wq(4)
    wq.halt()
    wq.noop()
    _, out = run_prog(p)
    assert bool(out.halted) and int(out.steps) == 1


def test_clock_matches_fig8_ordering_model():
    """Chain of k NOOPs: 1.21 + (k-1)*per-mode-fetch (paper Fig. 8)."""
    for mode, per in [(isa.ORD_WQ, 0.17), (isa.ORD_COMPLETION, 0.19),
                      (isa.ORD_DOORBELL, 0.54)]:
        p = assembler.Program(512)
        wq = p.add_wq(8, ordering=mode)
        for _ in range(5):
            wq.noop()
        _, out = run_prog(p)
        want = 1.21 + 4 * per
        np.testing.assert_allclose(float(out.clock[0]), want, rtol=1e-5)


def test_clock_matches_fig7_verb_latency():
    """Single WRITE = 1.60 us, single READ = 1.80 us (paper Fig. 7)."""
    for emit, want in [(lambda w, a, b: w.write(src=a, dst=b), 1.60),
                       (lambda w, a, b: w.read(src=a, dst=b), 1.80)]:
        p = assembler.Program(512)
        a, b = p.word(1), p.word(0)
        wq = p.add_wq(2)
        emit(wq, a, b)
        _, out = run_prog(p)
        np.testing.assert_allclose(float(out.clock[0]), want, rtol=1e-5)


def test_min_clock_scheduling_interleaves_pus():
    """Two independent WQs execute on parallel PU clocks, not serially."""
    p = assembler.Program(512)
    wq0 = p.add_wq(8)
    wq1 = p.add_wq(8)
    for _ in range(4):
        wq0.noop()
        wq1.noop()
    _, out = run_prog(p)
    t0, t1 = float(out.clock[0]), float(out.clock[1])
    serial = 2 * (1.21 + 3 * 0.17)
    assert max(t0, t1) < serial * 0.75   # parallel, not serial


def test_wq_recycling_wraps_around():
    """A recycled WQ re-executes its WRs (increment a counter many laps)."""
    p = assembler.Program(512)
    counter = p.word(0)
    wq = p.add_wq(2, recycled=True)
    wq.add(dst=counter, addend=1)
    wq.add(dst=counter, addend=1)
    spec, state = p.finalize()
    out = machine.run(spec, state, max_steps=100)
    assert int(out.steps) == 100           # fuel-bounded nontermination (T3)
    assert int(out.mem[counter]) == 100


def test_vmapped_batch_runs_independently():
    import jax
    p = assembler.Program(256)
    x = p.word(0)
    wq = p.add_wq(2)
    wq.add(dst=x, addend=1)
    spec, state = p.finalize()
    batch = jax.tree_util.tree_map(
        lambda a: jnp.stack([a] * 4), state)
    out = machine.run_batch(spec, batch, 16)
    assert np.asarray(out.mem[:, x]).tolist() == [1, 1, 1, 1]
