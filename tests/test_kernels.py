"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import isa
from repro.kernels.chain_vm import ops as chain_ops
from repro.kernels.decode_attention import ops as dec_ops
from repro.kernels.decode_attention import ref as dec_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.hopscotch import ops as hop_ops
from repro.kernels.rglru import ops as rg_ops
from repro.kernels.rglru import ref as rg_ref
from repro.kernels.rwkv6 import ops as wkv_ops
from repro.kernels.rwkv6 import ref as wkv_ref
from repro.kvstore import hopscotch as hs

RNG = np.random.RandomState(42)


def rand(shape, dtype, scale=1.0):
    x = RNG.randn(*shape).astype(np.float32) * scale
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# --- flash attention --------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    (1, 2, 2, 128, 64),      # B, H, KH, S, D
    (2, 4, 2, 256, 64),
    (1, 8, 1, 384, 128),     # MQA, non-pow2 seq (tail padding)
])
@pytest.mark.parametrize("mode,window", [
    ("causal", 0), ("causal", 64), ("full", 0)])
def test_flash_attention_sweep(shape, dtype, mode, window):
    b, h, kh, s, d = shape
    q, k, v = (rand((b, h, s, d), dtype), rand((b, kh, s, d), dtype),
               rand((b, kh, s, d), dtype))
    want = fa_ref.attention_reference(q, k, v, mode=mode, window=window)
    for impl in ("interpret", "blocked"):
        got = fa_ops.flash_attention(q, k, v, mode=mode, window=window,
                                     impl=impl, block_q=128, block_k=128)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=TOL[dtype], rtol=TOL[dtype], err_msg=f"{impl}")


def test_flash_attention_decode_length_mode():
    q = rand((2, 4, 1, 64), jnp.float32)
    k = rand((2, 2, 256, 64), jnp.float32)
    v = rand((2, 2, 256, 64), jnp.float32)
    lengths = jnp.asarray([100, 256], jnp.int32)
    want = fa_ref.attention_reference(q, k, v, mode="length",
                                      lengths=lengths)
    got = fa_ops.flash_attention(q, k, v, mode="length", lengths=lengths,
                                 impl="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_flash_attention_property(data):
    b = data.draw(st.integers(1, 2))
    kh = data.draw(st.sampled_from([1, 2]))
    g = data.draw(st.sampled_from([1, 2, 4]))
    s = data.draw(st.sampled_from([128, 192, 256]))
    d = data.draw(st.sampled_from([64, 128]))
    window = data.draw(st.sampled_from([0, 32, 100]))
    q = rand((b, kh * g, s, d), jnp.float32)
    k = rand((b, kh, s, d), jnp.float32)
    v = rand((b, kh, s, d), jnp.float32)
    want = fa_ref.attention_reference(q, k, v, mode="causal", window=window)
    got = fa_ops.flash_attention(q, k, v, mode="causal", window=window,
                                 impl="interpret", block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5,
                               rtol=1e-4)


# --- decode attention ---------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,kh,s,d", [(2, 4, 1, 512, 64),
                                        (1, 8, 2, 1024, 128)])
def test_decode_attention_sweep(b, h, kh, s, d, dtype):
    q = rand((b, h, 1, d), dtype)
    k = rand((b, kh, s, d), dtype)
    v = rand((b, kh, s, d), dtype)
    lengths = jnp.asarray(RNG.randint(1, s + 1, size=b), jnp.int32)
    want = dec_ref.decode_reference(q, k, v, lengths)
    got = dec_ops.decode_attention(q, k, v, lengths, impl="interpret")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_decode_sharded_combine_matches_unsharded():
    """The distributed-KV-get identity: per-shard partials combine exactly."""
    b, h, kh, s, d = 2, 4, 2, 1024, 64
    q = rand((b, h, 1, d), jnp.float32)
    k = rand((b, kh, s, d), jnp.float32)
    v = rand((b, kh, s, d), jnp.float32)
    lengths = jnp.asarray([700, 1024], jnp.int32)
    want = dec_ref.decode_reference(q, k, v, lengths)
    for n_shards in (2, 4, 8):
        w = s // n_shards
        parts = [dec_ops.decode_partial(q, k[:, :, i * w:(i + 1) * w],
                                        v[:, :, i * w:(i + 1) * w], lengths,
                                        kpos_offset=i * w, impl="interpret")
                 for i in range(n_shards)]
        got = dec_ops.combine_partials(parts).astype(q.dtype)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, err_msg=f"S={n_shards}")


# --- rwkv6 ----------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,t,n,m", [(2, 2, 64, 32, 32),
                                       (1, 4, 128, 64, 64)])
def test_wkv6_sweep(b, h, t, n, m, dtype):
    r = rand((b, h, t, n), dtype, 0.5)
    k = rand((b, h, t, n), dtype, 0.5)
    v = rand((b, h, t, m), dtype, 0.5)
    w = jnp.asarray(RNG.uniform(0.6, 0.999, (b, h, t, n)), dtype)
    u = rand((h, n), dtype, 0.5)
    want_o, want_s = wkv_ref.wkv6_reference(r, k, v, w, u)
    for impl in ("chunked", "interpret"):
        o, s_ = wkv_ops.wkv6(r, k, v, w, u, impl=impl)
        tol = 5e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(want_o, np.float32),
                                   atol=tol, rtol=tol, err_msg=impl)
        np.testing.assert_allclose(np.asarray(s_), np.asarray(want_s),
                                   atol=tol, rtol=tol, err_msg=impl)


def test_wkv6_decode_chain_matches_parallel():
    b, h, t, n, m = 1, 2, 16, 16, 16
    r = rand((b, h, t, n), jnp.float32, 0.5)
    k = rand((b, h, t, n), jnp.float32, 0.5)
    v = rand((b, h, t, m), jnp.float32, 0.5)
    w = jnp.asarray(RNG.uniform(0.6, 0.999, (b, h, t, n)), jnp.float32)
    u = rand((h, n), jnp.float32, 0.5)
    want_o, want_s = wkv_ref.wkv6_reference(r, k, v, w, u)
    st_ = jnp.zeros((b, h, n, m))
    outs = []
    for i in range(t):
        o1, st_ = wkv_ops.wkv6_decode_step(r[:, :, i], k[:, :, i],
                                           v[:, :, i], w[:, :, i], u, st_)
        outs.append(o1)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 2)),
                               np.asarray(want_o), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_), np.asarray(want_s),
                               atol=1e-5)


# --- rglru -----------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,d", [(2, 128, 64), (1, 256, 256)])
def test_rglru_sweep(b, t, d, dtype):
    a = jnp.asarray(RNG.uniform(0.4, 0.999, (b, t, d)), dtype)
    u = rand((b, t, d), dtype, 0.5)
    want_h, want_hT = rg_ref.rglru_reference(a, u)
    tol = 5e-5 if dtype == jnp.float32 else 5e-2
    for impl in ("chunked", "interpret", "assoc"):
        h, hT = rg_ops.rglru(a, u, impl=impl)
        np.testing.assert_allclose(np.asarray(h, np.float32),
                                   np.asarray(want_h, np.float32),
                                   atol=tol, rtol=tol, err_msg=impl)
        np.testing.assert_allclose(np.asarray(hT), np.asarray(want_hT),
                                   atol=tol, rtol=tol, err_msg=impl)


# --- hopscotch ---------------------------------------------------------------------

@pytest.mark.parametrize("n,b,v", [(1024, 128, 4), (2048, 256, 8)])
def test_hopscotch_kernel_sweep(n, b, v):
    t = hs.make_table(n, v, neighborhood=8)
    keys = RNG.choice(np.arange(1, 1 << 22), size=n // 3, replace=False)
    stored = {}
    for kk in keys:
        if t.insert(int(kk), [int(kk) % 251] * v):
            stored[int(kk)] = [int(kk) % 251] * v
    dk, dv = t.as_device()
    probe = np.concatenate([
        RNG.choice(keys, b - 16), RNG.randint(1 << 22, 1 << 23, 16)])
    q = jnp.asarray(probe, jnp.int32)
    want_f, want_v = hop_ops.hopscotch_lookup(dk, dv, q, 8, impl="ref")
    got_f, got_v = hop_ops.hopscotch_lookup(dk, dv, q, 8, impl="interpret",
                                            block_q=64, block_n=512)
    np.testing.assert_array_equal(np.asarray(got_f), np.asarray(want_f))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))


@settings(max_examples=10, deadline=None)
@given(nkeys=st.integers(1, 60), seed=st.integers(0, 1000))
def test_hopscotch_kernel_property(nkeys, seed):
    r = np.random.RandomState(seed)
    t = hs.make_table(256, 2, neighborhood=8)
    keys = r.choice(np.arange(1, 1 << 20), size=nkeys, replace=False)
    for kk in keys:
        t.insert(int(kk), [int(kk) % 97, int(kk) % 89])
    dk, dv = t.as_device()
    probe = np.resize(np.concatenate([keys, [1 << 21]]), 64)
    q = jnp.asarray(probe, jnp.int32)
    want = hop_ops.hopscotch_lookup(dk, dv, q, 8, impl="ref")
    got = hop_ops.hopscotch_lookup(dk, dv, q, 8, impl="interpret",
                                   block_q=64, block_n=256)
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


# --- chain_vm -------------------------------------------------------------------------

def _build_toy_chain():
    """A small self-modifying chain as a raw memory image."""
    from repro.core import assembler
    p = assembler.Program(256)
    x = p.word(5)
    y = p.word(0)
    flag = p.word(0)
    wq = p.add_wq(8)
    wq.read(src=x, dst=y)                  # y = 5
    wq.add(dst=y, addend=37)               # y = 42
    # self-modification: rewrite the NOOP below into WRITE_IMM(99 -> flag)
    new_ctrl = p.word(isa.pack_ctrl(isa.WRITE_IMM, 0))
    tgt = wq.future_wr_addr(1, "ctrl")
    wq.write(src=new_ctrl, dst=tgt, ln=1)
    wq.post(isa.NOOP, dst=flag, opa=99)
    wq.cas(dst=y, old=42, new=43)
    wq.halt()
    spec, state = p.finalize()
    return np.asarray(state.mem), spec.wq_bases[0], 8, dict(
        x=x, y=y, flag=flag)


def test_chain_vm_matches_core_semantics():
    mem, base, n_wrs, addrs = _build_toy_chain()
    batch = jnp.asarray(np.stack([mem] * 4))
    for impl in ("ref", "interpret"):
        out = chain_ops.run_chains(batch, wq_base=base, n_wrs=n_wrs,
                                   max_steps=8, impl=impl)
        got = np.asarray(out)
        assert (got[:, addrs["y"]] == 43).all(), impl
        assert (got[:, addrs["flag"]] == 99).all(), impl


def test_chain_vm_batch_independence():
    mem, base, n_wrs, addrs = _build_toy_chain()
    m2 = mem.copy()
    m2[addrs["x"]] = 100                    # different input for client 1
    batch = jnp.asarray(np.stack([mem, m2]))
    out = np.asarray(chain_ops.run_chains(batch, wq_base=base, n_wrs=n_wrs,
                                          max_steps=8, impl="interpret"))
    assert out[0, addrs["y"]] == 43
    assert out[1, addrs["y"]] == 137        # 100 + 37, CAS(42) failed
