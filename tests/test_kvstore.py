"""Tests: hopscotch/cuckoo tables, sharded store get paths (chain-VM redn
path vs oracle), capacity/drop semantics, isolation, failure resiliency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st
from jax.sharding import Mesh

from repro.core import programs
from repro.kvstore import cuckoo, hopscotch, store
from repro.rdma import failure, isolation


# --- hopscotch ---------------------------------------------------------------

def test_hopscotch_insert_lookup_roundtrip():
    t = hopscotch.make_table(64, 2, neighborhood=8)
    for k in range(1, 40):
        assert t.insert(k, [k, k * 2])
    keys, vals = t.as_device()
    q = jnp.arange(1, 50, dtype=jnp.int32)
    found, v = hopscotch.lookup(keys, vals, q, 8)
    for i, k in enumerate(range(1, 50)):
        if k < 40:
            assert bool(found[i]) and v[i].tolist() == [k, k * 2]
        else:
            assert not bool(found[i]) and v[i].tolist() == [0, 0]


def test_hopscotch_update_in_place():
    t = hopscotch.make_table(32, 2)
    t.insert(5, [1, 1])
    t.insert(5, [9, 9])
    keys, vals = t.as_device()
    _, v = hopscotch.lookup(keys, vals, jnp.asarray([5], jnp.int32), 8)
    assert v[0].tolist() == [9, 9]


@settings(max_examples=20, deadline=None)
@given(keys=st.lists(st.integers(1, 1 << 24), min_size=1, max_size=48,
                     unique=True))
def test_hopscotch_matches_dict(keys):
    t = hopscotch.make_table(128, 1, neighborhood=8)
    ref = {}
    for k in keys:
        if t.insert(k, [k % 1009]):
            ref[k] = k % 1009
    dk, dv = t.as_device()
    q = jnp.asarray(keys + [1 << 25], jnp.int32)
    found, v = hopscotch.lookup(dk, dv, q, 8)
    for i, k in enumerate(keys + [1 << 25]):
        if k in ref:
            assert bool(found[i]) and int(v[i, 0]) == ref[k]
        else:
            assert not bool(found[i])


# --- cuckoo -------------------------------------------------------------------

def test_cuckoo_insert_lookup():
    t = cuckoo.make_table(32, 2, ways=4)
    for k in range(1, 60):
        assert t.insert(k, [k, k + 1]), k
    dk, dv = t.as_device()
    found, v = cuckoo.lookup(dk, dv, jnp.arange(1, 60, dtype=jnp.int32))
    assert bool(jnp.all(found))
    np.testing.assert_array_equal(np.asarray(v[:, 0]), np.arange(1, 60))


# --- shard_of: python-int path == device path ---------------------------------

@settings(max_examples=50, deadline=None)
@given(key=st.integers(-(1 << 31), (1 << 31) - 1),
       n_shards=st.integers(1, 64))
def test_shard_of_int_matches_device(key, n_shards):
    """Negative keys (and any int32 bit pattern) must route to the same
    shard whichever side hashes them."""
    dev = int(store.shard_of(jnp.asarray([key], jnp.int32), n_shards)[0])
    assert store.shard_of(key, n_shards) == dev


@settings(max_examples=20, deadline=None)
@given(key=st.integers(1 << 32, (1 << 34)), n_shards=st.integers(1, 16))
def test_shard_of_wide_int_matches_device(key, n_shards):
    """>= 2**32 python keys hash like their int32 truncation (what the
    device would see)."""
    trunc = np.int64(key).astype(np.int32)
    dev = int(store.shard_of(jnp.asarray([trunc], jnp.int32), n_shards)[0])
    assert store.shard_of(key, n_shards) == dev


def test_shard_of_cross_path_deterministic():
    """Seeded sweep (runs even without hypothesis): every int32 pattern —
    negative included — and >= 2**32 keys route identically on both
    paths."""
    rng = np.random.RandomState(3)
    ks = np.concatenate([
        rng.randint(-(1 << 31), (1 << 31) - 1, 200, dtype=np.int64),
        np.asarray([0, -1, 1 << 32 | 5, (1 << 33) - 1, 0xFFFFFFFF],
                   np.int64)])
    for k in ks.tolist():
        trunc = np.int64(k).astype(np.int32)
        for n in (1, 3, 8, 64):
            dev = int(store.shard_of(jnp.asarray([trunc], jnp.int32), n)[0])
            assert store.shard_of(k, n) == dev, (k, n)


# --- the per-shard chain program vs the jnp oracle -----------------------------

def test_hopscotch_server_bit_exact_with_oracle():
    t = hopscotch.make_table(64, 2, neighborhood=8)
    for k in range(1, 40):
        assert t.insert(k, [k, k * 2])
    keys, vals = t.as_device()
    srv = programs.build_hopscotch_server(64, 2, 8)
    # hits, misses, and query 0 — which must be a miss on both (the chain's
    # dynamic found-flag rows de-alias empty buckets from real hits)
    q = jnp.asarray(list(range(1, 50)) + [0], jnp.int32)
    found, v = srv.get_many(keys, vals, q, hopscotch.bucket_of(q, 64))
    rfound, rv = hopscotch.lookup(keys, vals, q, 8)
    np.testing.assert_array_equal(np.asarray(found), np.asarray(rfound))
    np.testing.assert_array_equal(np.asarray(v), np.asarray(rv))


# --- sharded store: the three get paths ---------------------------------------

@pytest.fixture(scope="module")
def kv_setup():
    kv = store.ShardedKV.build(n_shards=1, buckets_per_shard=128,
                               val_words=2)
    rng = np.random.RandomState(0)
    keys = rng.choice(np.arange(1, 1 << 16), size=60, replace=False)
    for k in keys:
        kv.set(int(k), [int(k) % 251, int(k) % 241])
    return kv, keys


@pytest.mark.parametrize("method", ["redn", "one_sided", "two_sided"])
def test_sharded_get_paths_agree_with_reference(kv_setup, method):
    kv, keys = kv_setup
    mesh = Mesh(np.array(jax.devices()[:1]), ("kv",))
    dk, dv = kv.device_arrays()
    rng = np.random.RandomState(1)
    probe = np.concatenate([rng.choice(keys, 20), [99999, 77777]])
    q = jnp.asarray(probe[None, :], jnp.int32)
    res = store.sharded_get(mesh, "kv", dk, dv, q, method=method)
    rfound, rvals = store.reference_get(kv, probe)
    np.testing.assert_array_equal(np.asarray(res.found[0]), rfound)
    np.testing.assert_array_equal(np.asarray(res.values[0]), rvals)
    assert bool(np.asarray(res.ok).all())
    assert int(res.dropped[0]) == 0 and int(res.deferred[0]) == 0


def test_get_paths_identical_across_methods(kv_setup):
    kv, keys = kv_setup
    mesh = Mesh(np.array(jax.devices()[:1]), ("kv",))
    dk, dv = kv.device_arrays()
    q = jnp.asarray(keys[None, :32], jnp.int32)
    outs = {m: store.sharded_get(mesh, "kv", dk, dv, q, method=m)
            for m in ("redn", "one_sided", "two_sided")}
    for m in ("one_sided", "two_sided"):
        np.testing.assert_array_equal(np.asarray(outs["redn"].values),
                                      np.asarray(outs[m].values))


@pytest.mark.parametrize("method", ["redn", "one_sided", "two_sided"])
def test_capacity_overflow_drops_are_flagged_not_missed(kv_setup, method):
    """All three paths: over-capacity requests come back ok=False (and
    counted in dropped); admitted rows still agree with the oracle.  A
    dropped hit must never read as found=False with ok silently True."""
    kv, keys = kv_setup
    mesh = Mesh(np.array(jax.devices()[:1]), ("kv",))
    dk, dv = kv.device_arrays()
    probe = keys[:24]                       # all hits -> drops would alias
    q = jnp.asarray(probe[None, :], jnp.int32)
    cap = 9
    res = store.sharded_get(mesh, "kv", dk, dv, q, method=method,
                            capacity=cap)
    ok = np.asarray(res.ok[0])
    assert ok.sum() == cap                  # one shard: first cap survive
    assert int(res.dropped[0]) == len(probe) - cap
    rfound, rvals = store.reference_get(kv, probe)
    np.testing.assert_array_equal(np.asarray(res.found[0])[ok], rfound[ok])
    np.testing.assert_array_equal(np.asarray(res.values[0])[ok], rvals[ok])
    # every dropped row is a *hit* in the table: ok=False is the only thing
    # separating it from a miss
    assert rfound[~ok].all()
    assert not np.asarray(res.found[0])[~ok].any()


@pytest.mark.parametrize("method", ["redn", "one_sided", "two_sided"])
def test_query_of_empty_key_is_a_miss_on_every_path(kv_setup, method):
    """Regression: key 0 is the EMPTY bucket marker — a query of 0 used to
    ghost-hit empty buckets and report found=True with garbage-zero
    values on all three get paths."""
    kv, keys = kv_setup
    mesh = Mesh(np.array(jax.devices()[:1]), ("kv",))
    dk, dv = kv.device_arrays()
    q = jnp.asarray(np.asarray([0, int(keys[0]), 0], np.int32)[None])
    res = store.sharded_get(mesh, "kv", dk, dv, q, method=method)
    assert bool(np.asarray(res.ok).all())
    found = np.asarray(res.found[0])
    assert not found[0] and not found[2]
    assert found[1]                         # real keys still hit
    np.testing.assert_array_equal(np.asarray(res.values[0][0]), [0, 0])


def test_query_zero_miss_in_lookup_and_reference_oracle(kv_setup):
    kv, _ = kv_setup
    dk, dv = kv.device_arrays()
    found, vals = hopscotch.lookup(dk[0], dv[0],
                                   jnp.asarray([0], jnp.int32), 8)
    assert not bool(found[0])
    rfound, rvals = store.reference_get(kv, np.asarray([0], np.int32))
    assert not rfound[0] and (rvals[0] == 0).all()


def test_capacity_zero_drops_everything(kv_setup):
    """Regression: ``capacity or b_local`` silently promoted an explicit
    capacity=0 to the default batch size; 0 is a legal drop-all limit."""
    kv, keys = kv_setup
    mesh = Mesh(np.array(jax.devices()[:1]), ("kv",))
    dk, dv = kv.device_arrays()
    q = jnp.asarray(keys[None, :16], jnp.int32)
    res = store.sharded_get(mesh, "kv", dk, dv, q, capacity=0)
    assert not np.asarray(res.ok).any()
    assert not np.asarray(res.found).any()
    assert int(res.dropped[0]) == 16 and int(res.deferred[0]) == 0
    sres, nk, nv = store.sharded_set(
        mesh, "kv", dk, dv, q, jnp.zeros(q.shape + (2,), jnp.int32),
        capacity=0)
    assert not np.asarray(sres.ok).any()
    assert int(sres.dropped[0]) == 16
    np.testing.assert_array_equal(np.asarray(nk), np.asarray(dk))


def test_rtt_model():
    assert store.RTTS["redn"] == 1
    assert store.RTTS["one_sided"] == 2
    assert store.HOST_SERVICE["two_sided"]
    assert not store.HOST_SERVICE["redn"]


def test_set_rejects_wide_keys():
    kv = store.ShardedKV.build(n_shards=1, buckets_per_shard=8, val_words=1)
    with pytest.raises(ValueError):
        kv.set(1 << 24, [1])
    with pytest.raises(ValueError):
        kv.set(0, [1])


# --- isolation ------------------------------------------------------------------

def test_token_bucket_limits_heavy_client():
    st0 = isolation.init(n_clients=2, burst=4.0)
    # client 0 fires 8 requests at t=0; client 1 fires 2
    clients = jnp.asarray([0] * 8 + [1] * 2, jnp.int32)
    st1, admitted = isolation.admit(st0, clients, 0.0, rate_per_us=0.001,
                                    burst=4.0)
    adm = np.asarray(admitted)
    assert adm[:4].all() and not adm[4:8].any()    # heavy client capped
    assert adm[8:].all()                           # light client unaffected

    # after enough time the bucket refills
    st2, admitted2 = isolation.admit(st1, jnp.asarray([0], jnp.int32),
                                     8000.0, rate_per_us=0.001, burst=4.0)
    assert bool(admitted2[0])


def test_sharded_get_isolated_defers_misbehaving_client(kv_setup):
    """§5.5 through the store: the flooder is deferred to its burst, the
    victims are all served by the owner chain and match the oracle."""
    kv, keys = kv_setup
    mesh = Mesh(np.array(jax.devices()[:1]), ("kv",))
    dk, dv = kv.device_arrays()
    flood, burst, cap = 20, 4.0, 12
    probe = np.concatenate([np.full(flood, keys[0]), keys[1:9]]).astype(
        np.int32)
    clients = np.asarray([0] * flood + list(range(1, 9)), np.int32)
    q = jnp.asarray(probe[None])
    bucket = isolation.init(n_clients=9, burst=burst)
    res, bucket = store.sharded_get_isolated(
        mesh, "kv", dk, dv, q, jnp.asarray(clients[None]), bucket,
        now_us=0.0, rate_per_us=0.001, burst=burst, capacity=cap)
    ok = np.asarray(res.ok[0])
    victim = clients > 0
    assert ok[victim].all()                     # victims all served
    assert ok[~victim].sum() == int(burst)      # flooder capped at burst
    assert int(res.deferred[0]) == flood - int(burst)
    assert int(res.dropped[0]) == 0             # admitted all fit capacity
    rfound, rvals = store.reference_get(kv, probe)
    np.testing.assert_array_equal(np.asarray(res.found[0])[ok], rfound[ok])
    np.testing.assert_array_equal(np.asarray(res.values[0])[ok], rvals[ok])
    # without admission, the flood occupies every slot: victims dropped
    res_off = store.sharded_get(mesh, "kv", dk, dv, q, capacity=cap)
    assert not np.asarray(res_off.ok[0])[victim].any()


# --- failure resiliency -----------------------------------------------------------

def test_service_survives_host_crash():
    items = [(k, [k * 3, k * 5]) for k in range(1, 9)]
    svc = failure.DeviceResidentService.start(items)
    assert svc.get(4).tolist() == [12, 20]
    svc.crash_host()                       # Memcached dies
    assert not svc.host_alive()
    for k in range(1, 9):                  # zero-interruption serving
        assert svc.get(k).tolist() == [k * 3, k * 5]
    svc.restart_host()
    assert svc.host_alive()
    assert svc.get(2).tolist() == [6, 10]
    assert svc.cold_restart_downtime_s() >= 2.0   # what vanilla would pay


def test_sharded_service_survives_host_crash():
    """§5.6 on the *sharded* store: kill the host driver and the sharded
    chain-VM gets — and the chain-offloaded fast-path sets — keep
    serving; only displacement needs the driver."""
    items = [(k, [k * 3, k * 5]) for k in range(1, 17)]
    svc = failure.ShardedKVService.start(items)
    q = np.arange(1, 21, dtype=np.int32)
    before = svc.get_many(q)
    svc.crash_host()
    assert not svc.host_alive()
    after = svc.get_many(q)                # zero-interruption serving
    np.testing.assert_array_equal(np.asarray(before.found),
                                  np.asarray(after.found))
    np.testing.assert_array_equal(np.asarray(before.values),
                                  np.asarray(after.values))
    assert bool(np.asarray(after.ok).all())
    assert np.asarray(after.found[0])[:16].all()
    assert not np.asarray(after.found[0])[16:].any()
    # the writer chain needs no host: update and insert serve driver-dead
    assert svc.set(99, [1, 2])             # in-neighborhood insert
    assert svc.set(4, [40, 41])            # update
    got = svc.get_many(np.asarray([99, 4], np.int32))
    assert bool(got.found[0][0]) and bool(got.found[0][1])
    np.testing.assert_array_equal(np.asarray(got.values[0]),
                                  [[1, 2], [40, 41]])
    svc.restart_host()
    assert svc.set(99, [7, 8])
    np.testing.assert_array_equal(
        np.asarray(svc.get_many(np.asarray([99], np.int32)).values[0][0]),
        [7, 8])
