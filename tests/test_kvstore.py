"""Tests: hopscotch/cuckoo tables, sharded store get paths, isolation,
failure resiliency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st
from jax.sharding import Mesh

from repro.kvstore import cuckoo, hopscotch, store
from repro.rdma import failure, isolation


# --- hopscotch ---------------------------------------------------------------

def test_hopscotch_insert_lookup_roundtrip():
    t = hopscotch.make_table(64, 2, neighborhood=8)
    for k in range(1, 40):
        assert t.insert(k, [k, k * 2])
    keys, vals = t.as_device()
    q = jnp.arange(1, 50, dtype=jnp.int32)
    found, v = hopscotch.lookup(keys, vals, q, 8)
    for i, k in enumerate(range(1, 50)):
        if k < 40:
            assert bool(found[i]) and v[i].tolist() == [k, k * 2]
        else:
            assert not bool(found[i]) and v[i].tolist() == [0, 0]


def test_hopscotch_update_in_place():
    t = hopscotch.make_table(32, 2)
    t.insert(5, [1, 1])
    t.insert(5, [9, 9])
    keys, vals = t.as_device()
    _, v = hopscotch.lookup(keys, vals, jnp.asarray([5], jnp.int32), 8)
    assert v[0].tolist() == [9, 9]


@settings(max_examples=20, deadline=None)
@given(keys=st.lists(st.integers(1, 1 << 24), min_size=1, max_size=48,
                     unique=True))
def test_hopscotch_matches_dict(keys):
    t = hopscotch.make_table(128, 1, neighborhood=8)
    ref = {}
    for k in keys:
        if t.insert(k, [k % 1009]):
            ref[k] = k % 1009
    dk, dv = t.as_device()
    q = jnp.asarray(keys + [1 << 25], jnp.int32)
    found, v = hopscotch.lookup(dk, dv, q, 8)
    for i, k in enumerate(keys + [1 << 25]):
        if k in ref:
            assert bool(found[i]) and int(v[i, 0]) == ref[k]
        else:
            assert not bool(found[i])


# --- cuckoo -------------------------------------------------------------------

def test_cuckoo_insert_lookup():
    t = cuckoo.make_table(32, 2, ways=4)
    for k in range(1, 60):
        assert t.insert(k, [k, k + 1]), k
    dk, dv = t.as_device()
    found, v = cuckoo.lookup(dk, dv, jnp.arange(1, 60, dtype=jnp.int32))
    assert bool(jnp.all(found))
    np.testing.assert_array_equal(np.asarray(v[:, 0]), np.arange(1, 60))


# --- sharded store: the three get paths ---------------------------------------

@pytest.fixture(scope="module")
def kv_setup():
    kv = store.ShardedKV.build(n_shards=1, buckets_per_shard=128,
                               val_words=2)
    rng = np.random.RandomState(0)
    keys = rng.choice(np.arange(1, 1 << 16), size=60, replace=False)
    for k in keys:
        kv.set(int(k), [int(k) % 251, int(k) % 241])
    return kv, keys


@pytest.mark.parametrize("method", ["redn", "one_sided", "two_sided"])
def test_sharded_get_paths_agree_with_reference(kv_setup, method):
    kv, keys = kv_setup
    mesh = Mesh(np.array(jax.devices()[:1]), ("kv",))
    dk, dv = kv.device_arrays()
    rng = np.random.RandomState(1)
    probe = np.concatenate([rng.choice(keys, 20), [99999, 77777]])
    q = jnp.asarray(probe[None, :], jnp.int32)
    found, vals, dropped = store.sharded_get(mesh, "kv", dk, dv, q,
                                             method=method)
    rfound, rvals = store.reference_get(kv, probe)
    np.testing.assert_array_equal(np.asarray(found[0]), rfound)
    np.testing.assert_array_equal(np.asarray(vals[0]), rvals)
    assert int(dropped[0]) == 0


def test_get_paths_identical_across_methods(kv_setup):
    kv, keys = kv_setup
    mesh = Mesh(np.array(jax.devices()[:1]), ("kv",))
    dk, dv = kv.device_arrays()
    q = jnp.asarray(keys[None, :32], jnp.int32)
    outs = {m: store.sharded_get(mesh, "kv", dk, dv, q, method=m)
            for m in ("redn", "one_sided", "two_sided")}
    for m in ("one_sided", "two_sided"):
        np.testing.assert_array_equal(np.asarray(outs["redn"][1]),
                                      np.asarray(outs[m][1]))


def test_rtt_model():
    assert store.RTTS["redn"] == 1
    assert store.RTTS["one_sided"] == 2
    assert store.HOST_SERVICE["two_sided"]
    assert not store.HOST_SERVICE["redn"]


# --- isolation ------------------------------------------------------------------

def test_token_bucket_limits_heavy_client():
    st0 = isolation.init(n_clients=2, burst=4.0)
    # client 0 fires 8 requests at t=0; client 1 fires 2
    clients = jnp.asarray([0] * 8 + [1] * 2, jnp.int32)
    st1, admitted = isolation.admit(st0, clients, 0.0, rate_per_us=0.001,
                                    burst=4.0)
    adm = np.asarray(admitted)
    assert adm[:4].all() and not adm[4:8].any()    # heavy client capped
    assert adm[8:].all()                           # light client unaffected

    # after enough time the bucket refills
    st2, admitted2 = isolation.admit(st1, jnp.asarray([0], jnp.int32),
                                     8000.0, rate_per_us=0.001, burst=4.0)
    assert bool(admitted2[0])


# --- failure resiliency -----------------------------------------------------------

def test_service_survives_host_crash():
    items = [(k, [k * 3, k * 5]) for k in range(1, 9)]
    svc = failure.DeviceResidentService.start(items)
    assert svc.get(4).tolist() == [12, 20]
    svc.crash_host()                       # Memcached dies
    assert not svc.host_alive()
    for k in range(1, 9):                  # zero-interruption serving
        assert svc.get(k).tolist() == [k * 3, k * 5]
    svc.restart_host()
    assert svc.host_alive()
    assert svc.get(2).tolist() == [6, 10]
    assert svc.cold_restart_downtime_s() >= 2.0   # what vanilla would pay
