"""End-to-end behaviour tests for the paper's system.

These are the paper's headline behaviours exercised through the public
API, end to end: offloaded gets through the full chain pipeline, the
serving path surviving a host crash mid-stream, the isolation guarantee
under a greedy tenant, and the LM-serving integration (decode as a
distributed KV get).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import machine, programs
from repro.data.pipeline import kv_request_stream
from repro.models import model as M
from repro.rdma import failure
from repro.serve import ServeEngine


def test_e2e_offloaded_get_pipeline():
    """Client -> SEND -> chain executes -> value lands -> miss semantics,
    across many random keys, through the real Fig. 9 program."""
    off = programs.build_hash_lookup(n_buckets=128, val_len=4)
    rng = np.random.RandomState(0)
    keys = rng.choice(np.arange(1, 1 << 20), 48, replace=False)
    stored = {}
    for k in keys:
        if off.insert(int(k), [int(k) & 0xFFFF, 1, 2, 3]):
            stored[int(k)] = [int(k) & 0xFFFF, 1, 2, 3]
    hits = misses = 0
    for k in list(stored)[:24] + [1 << 21, (1 << 21) + 1]:
        val, out = off.get(int(k))
        if k in stored:
            assert val.tolist() == stored[k]
            hits += 1
        else:
            assert val.tolist() == [0, 0, 0, 0]
            misses += 1
        # the host CPU executed nothing: every step was a chain verb
        assert int(out.steps) > 0
    assert hits == 24 and misses == 2


def test_e2e_serving_survives_crash_under_load():
    """Zipf gets keep succeeding while the host driver dies and returns."""
    items = [(k, [k * 7, k * 11]) for k in range(1, 33)]
    svc = failure.DeviceResidentService.start(items, n_buckets=64)
    stream = kv_request_stream(32, 16, seed=3)
    failures = 0
    for step in range(6):
        if step == 2:
            svc.crash_host()
        if step == 4:
            svc.restart_host()
        _, keys = next(stream)
        for k in keys[:4]:
            got = svc.get(int(k))
            if got.tolist() != [int(k) * 7, int(k) * 11]:
                failures += 1
    assert failures == 0


def test_e2e_lm_serving_with_isolation_and_failover():
    """The LM decode engine: throttled greedy tenant, decode through a
    driver crash, token stream continuity."""
    cfg = registry.smoke_config("smollm-135m")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, s_max=48, n_slots=4, n_clients=2,
                      rate_per_us=0.1, burst=3.0)
    admitted = eng.admit([0, 0, 0, 0, 1])
    assert admitted == [True, True, True, False, True]   # greedy capped
    eng.add_request(0, 0, 3)
    eng.add_request(1, 1, 5)
    toks = []
    for i in range(8):
        if i == 4:
            eng.crash_host_driver()
        toks.append(eng.step()[:2].tolist())
    assert not eng.host_alive()
    assert len(toks) == 8                                # no interruption
    assert eng.stats["throttled"] == 1


def test_e2e_decode_equals_prefill_continuation_all_families():
    """Across one arch per family: serve_step continues prefill exactly
    (the cache IS a correct distributed KV store)."""
    for arch in ("qwen3-1.7b", "mixtral-8x7b", "rwkv6-7b",
                 "recurrentgemma-9b"):
        cfg = registry.smoke_config(arch)
        params = M.init_params(jax.random.PRNGKey(1), cfg)
        rng = np.random.RandomState(2)
        toks = jnp.asarray(rng.randint(1, cfg.vocab_size, (1, 10)),
                           jnp.int32)
        full, _, _ = M.forward(params, {"tokens": toks}, cfg)
        last, caches, lengths = M.prefill(
            params, {"tokens": toks[:, :9]}, cfg, s_max=12)
        lg, _ = M.decode_step(params, toks[:, 9], caches, lengths + 1, cfg)
        tol = 2e-2 if cfg.dtype == "bfloat16" else 2e-3
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, -1]), atol=tol,
                                   rtol=tol, err_msg=arch)
