"""Lifecycle workload scenario (benchmarks/lifecycle.py)."""
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import lifecycle  # noqa: E402


def test_lifecycle_round_self_checks_smoke():
    """One small mixed run: sets/deletes/sweeps bit-exact with the host
    oracle, TTL reads match lookup_ttl, and the driver stays dead."""
    m = lifecycle.run_lifecycle(batch=10, rounds=2, seed=11)
    assert all(m["checks"].values()), m["checks"]
    assert m["driver_dead_throughout"]


@pytest.mark.slow
def test_lifecycle_benchmark_long_run(tmp_path):
    """The full run records the lifecycle rows and checks into the
    BENCH json."""
    out = tmp_path / "BENCH_chains.json"
    results = lifecycle.main(out_path=str(out), long=True)
    assert out.exists()
    lc = results["lifecycle"]
    assert lc["mixed"]["reclaimed_total"] > 0
    assert lc["sweeper_throughput"]["buckets_per_s"] > 0
    for name, ok in results["checks"].items():
        if name.startswith("lifecycle"):
            assert ok, name
