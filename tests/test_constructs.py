"""Tests for RedN constructs: if (Fig 4), while (Fig 5/6), recycling (§3.4),
mov emulation (Appendix A), and Table 2 verb budgets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import assembler, constructs, isa, machine


def build_if(x, y):
    """Fig 4 program: resp = 1 if x == y else 0 (default)."""
    p = assembler.Program(512)
    one = p.word(1)
    resp = p.word(0)
    mod = p.add_wq(4, managed=True, ordering=isa.ORD_DOORBELL)
    ctl = p.add_wq(8)
    refs = constructs.emit_if(ctl, mod, x=x, y=y, then_src=one,
                              then_dst=resp)
    return p, resp, refs


@pytest.mark.parametrize("x,y,want", [(3, 3, 1), (3, 4, 0), (0, 0, 1),
                                      (0xFFFFFF, 0xFFFFFF, 1),
                                      (0xFFFFFF, 0xFFFFFE, 0)])
def test_if_construct(x, y, want):
    p, resp, _ = build_if(x, y)
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, 64)
    assert int(out.mem[resp]) == want


@settings(max_examples=25, deadline=None)
@given(x=st.integers(0, isa.ID_MASK), y=st.integers(0, isa.ID_MASK))
def test_if_matches_python_semantics(x, y):
    p, resp, _ = build_if(x, y)
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, 64)
    assert int(out.mem[resp]) == (1 if x == y else 0)


def test_if_budget_matches_table2():
    """if = 1C + 1A + 3E: WAIT(input) + ENABLE + WAIT(before R3)."""
    p = assembler.Program(512)
    one = p.word(1)
    resp = p.word(0)
    inp = p.add_wq(2)
    trigger = inp.noop()                 # stands in for the input RECV
    mod = p.add_wq(4, managed=True, ordering=isa.ORD_DOORBELL)
    ctl = p.add_wq(8)
    refs = constructs.emit_if(ctl, mod, x=1, y=2, then_src=one,
                              then_dst=resp, wait_for=trigger)
    resp_wq = p.add_wq(4)
    resp_wq.wait_for(refs.cond_wr)       # E3: gate the return WR
    resp_wq.send(src=resp, ln=1, dst_region=resp, target_qp=-1)
    b = p.budget()
    # A: the CAS; E: WAIT(input)+ENABLE+WAIT(R3); C: cond NOOP + the
    # surrounding trigger NOOP and R3 SEND (scaffolding, not the if itself)
    assert b["A"] == 1 and b["E"] == 3 and b["C"] == 3


def search_outcome(keys, x, use_break, max_steps=2048):
    n = len(keys)
    p = assembler.Program(2048)
    resp = p.word(-1 & 0xFFFFFF)
    body = p.add_wq(2 * n + 2)
    ctl = p.add_wq(2 * n + 2)
    mod = p.add_wq(n + 2, managed=True, ordering=isa.ORD_DOORBELL)
    constructs.emit_while_search_unrolled(
        p, body, ctl, mod, n_iters=n, keys=keys, x=x, resp_region=resp,
        resp_payloads=list(range(n)), use_break=use_break)
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, max_steps)
    return int(out.mem[resp]), out


@pytest.mark.parametrize("use_break", [False, True])
def test_while_search_finds_key(use_break):
    keys = [11, 22, 33, 44]
    for x, want in [(11, 0), (33, 2), (44, 3), (99, 16777215)]:
        got, _ = search_outcome(keys, x, use_break)
        assert got == want, (x, want, got, use_break)


def test_while_break_stops_subsequent_iterations():
    """With break, a hit at i stops CASes for i+2.. (Fig 6 semantics)."""
    keys = [5, 6, 7, 8, 9, 10]
    _, out_hit = search_outcome(keys, 6, use_break=True)
    _, out_miss = search_outcome(keys, 99, use_break=True)
    # fewer CAS verbs executed when breaking early
    assert int(out_hit.verb_counts[isa.CAS]) < int(
        out_miss.verb_counts[isa.CAS])


def test_while_nobreak_executes_all_iterations():
    keys = [5, 6, 7, 8, 9, 10]
    _, out = search_outcome(keys, 5, use_break=False)
    assert int(out.verb_counts[isa.CAS]) == len(keys)


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_while_search_matches_python(data):
    n = data.draw(st.integers(1, 6))
    keys = data.draw(st.lists(st.integers(1, 1000), min_size=n, max_size=n,
                              unique=True))
    x = data.draw(st.sampled_from(keys + [1001]))
    use_break = data.draw(st.booleans())
    got, _ = search_outcome(keys, x, use_break)
    want = keys.index(x) if x in keys else 0xFFFFFF
    assert got == want


def test_while_unrolled_budget_matches_table2():
    """per-iteration: 1C + 1A + 3E (Table 2, while/unrolled row)."""
    n = 4
    p = assembler.Program(2048)
    resp = p.word(0)
    body = p.add_wq(2 * n + 2)
    ctl = p.add_wq(2 * n + 2)
    mod = p.add_wq(n + 2, managed=True, ordering=isa.ORD_DOORBELL)
    constructs.emit_while_search_unrolled(
        p, body, ctl, mod, n_iters=n, keys=[1, 2, 3, 4], x=9,
        resp_region=resp, resp_payloads=list(range(n)), use_break=False)
    b = p.budget()
    assert b["A"] == n                 # 1 CAS per iteration
    assert b["C"] == n                 # 1 conditional NOOP per iteration
    assert b["E"] == 3 * n - 1         # 3E per iteration (first gate elided)


def test_recycled_loop_fires_on_match_and_rearms():
    """§3.4: recycled predicate loop with no CPU involvement."""
    p = assembler.Program(1024)
    datum = p.word(7)
    marker = p.word(111)
    hits = p.word(0)
    loop = constructs.emit_recycled_predicate_loop(
        p, data_addr=datum, x=7, then_src=marker, then_dst=hits)
    # initial lap: cond id=0 (placeholder) != 7 packed -> first CAS misses;
    # the refetch READ loads mem[datum]=7 into the cond id, so lap 2 hits.
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, max_steps=64)
    assert int(out.steps) == 64              # nontermination (fuel-bounded)
    assert int(out.mem[hits]) == 111         # the then-WRITE fired

    # change the datum -> predicate false -> then-WRITE stops firing
    st1 = out._replace(mem=out.mem.at[datum].set(8),
                       steps=jnp.zeros((), jnp.int32))
    st1 = machine.run(spec, st1, max_steps=16)   # flush the in-flight lap
    st1 = st1._replace(mem=st1.mem.at[hits].set(0),
                       steps=jnp.zeros((), jnp.int32))
    out2 = machine.run(spec, st1, max_steps=64)
    assert int(out2.steps) == 64                 # still looping...
    assert int(out2.mem[hits]) == 0              # ...but never firing


def test_recycled_budget():
    p = assembler.Program(1024)
    datum = p.word(7)
    constructs.emit_recycled_predicate_loop(
        p, data_addr=datum, x=7, then_src=datum, then_dst=datum)
    b = p.budget()
    # our adaptation: 3C + 2A + 1E (+2 pad NOOPs) per lap; paper: 3C+2A+4E.
    assert b["A"] == 2 and b["E"] == 1 and b["C"] == 3 + 2


# --- CAS-claim (§3.5) --------------------------------------------------------

def _build_claim(cell_value, expect=0, new=42):
    """resp = 1 iff the claim CAS won the cell (expect -> new)."""
    p = assembler.Program(512)
    one = p.word(1)
    resp = p.word(0)
    cell = p.word(cell_value)
    mod = p.add_wq(4, managed=True, ordering=isa.ORD_DOORBELL)
    ctl = p.add_wq(8)
    refs = constructs.emit_cas_claim(ctl, mod, cell=cell, expect=expect,
                                     new=new, then_src=one, then_dst=resp)
    ctl.enable(mod, upto=mod.n_posted)
    return p, resp, cell, refs


@pytest.mark.parametrize("cell_value,won", [(0, True), (7, False),
                                            (42, False)])
def test_cas_claim_branches_on_ownership(cell_value, won):
    """A winning claim swaps the cell and fires the then-branch; a losing
    one leaves both the cell and the conditional untouched."""
    p, resp, cell, _ = _build_claim(cell_value)
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, 64)
    assert int(out.mem[resp]) == (1 if won else 0)
    assert int(out.mem[cell]) == (42 if won else cell_value)


def test_cas_claim_nonzero_expect():
    """expect != 0 also works: the return-old in the cond ctrl reads as
    pack(NOOP, old), which the test-CAS compares against pack(NOOP,
    expect)."""
    for cell_value, won in [(9, True), (10, False)]:
        p, resp, cell, _ = _build_claim(cell_value, expect=9, new=11)
        spec, st0 = p.finalize()
        out = machine.run(spec, st0, 64)
        assert int(out.mem[resp]) == (1 if won else 0)
        assert int(out.mem[cell]) == (11 if won else cell_value)


def test_cas_claim_patched_cell_and_value():
    """The hopscotch-writer usage: cell address and claim value arrive at
    run time through the patch addresses the refs expose."""
    p = assembler.Program(512)
    one = p.word(1)
    resp = p.word(0)
    cell = p.word(0)
    cell_addr_w = p.word(cell)                # "scattered" cell address
    key_w = p.word(1234)
    mod = p.add_wq(4, managed=True, ordering=isa.ORD_DOORBELL)
    drv = p.add_wq(4)
    ctl = p.add_wq(8)
    ctl.wait(drv, 2)                          # patches land first
    refs = constructs.emit_cas_claim(ctl, mod, then_src=one, then_dst=resp)
    ctl.enable(mod, upto=mod.n_posted)
    drv.write(src=cell_addr_w, dst=refs.cell_dst_addr)
    drv.write(src=key_w, dst=refs.new_opb_addr)
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, 64)
    assert int(out.mem[resp]) == 1
    assert int(out.mem[cell]) == 1234


# --- mov emulation (Appendix A) ---------------------------------------------

def test_mov_immediate():
    p = assembler.Program(512)
    r = p.word(0)
    wq = p.add_wq(2)
    constructs.emit_mov_imm(wq, 77, r)
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, 16)
    assert int(out.mem[r]) == 77


def test_mov_indirect():
    p = assembler.Program(512)
    cell = p.word(345)            # the pointee
    r_src = p.word(0)             # register holding &cell
    r_dst = p.word(0)
    p._data_init[r_src] = cell    # r_src := &cell
    mod = p.add_wq(4, managed=True, ordering=isa.ORD_DOORBELL)
    ctl = p.add_wq(4)
    constructs.emit_mov_indirect(ctl, mod, r_src, r_dst)
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, 32)
    assert int(out.mem[r_dst]) == 345


def test_mov_indexed():
    p = assembler.Program(512)
    arr = p.alloc(4, [10, 20, 30, 40])
    r_src = p.word(arr)           # base address
    r_off = p.word(2)             # offset
    r_dst = p.word(0)
    mod = p.add_wq(4, managed=True, ordering=isa.ORD_DOORBELL)
    ctl = p.add_wq(8)
    constructs.emit_mov_indexed(ctl, mod, r_src, r_off, r_dst)
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, 64)
    assert int(out.mem[r_dst]) == 30   # [r_src + r_off] = arr[2]


def test_mov_store_indirect():
    p = assembler.Program(512)
    cell = p.word(0)
    r_val = p.word(55)
    r_ptr = p.word(cell)
    mod = p.add_wq(4, managed=True, ordering=isa.ORD_DOORBELL)
    ctl = p.add_wq(4)
    constructs.emit_mov_store_indirect(ctl, mod, r_val, r_ptr)
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, 32)
    assert int(out.mem[cell]) == 55


@settings(max_examples=15, deadline=None)
@given(vals=st.lists(st.integers(0, 1000), min_size=4, max_size=4),
       off=st.integers(0, 3))
def test_mov_indexed_matches_python(vals, off):
    p = assembler.Program(512)
    arr = p.alloc(4, vals)
    r_src = p.word(arr)
    r_off = p.word(off)
    r_dst = p.word(0)
    mod = p.add_wq(4, managed=True, ordering=isa.ORD_DOORBELL)
    ctl = p.add_wq(8)
    constructs.emit_mov_indexed(ctl, mod, r_src, r_off, r_dst)
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, 64)
    assert int(out.mem[r_dst]) == vals[off]


# --- enable-branch (Calc-verb inequality conditional) -------------------------

def _build_branch(v, threshold):
    """if (v <= thr) then wq_a writes 1 else wq_b writes 2 into resp."""
    p = assembler.Program(512)
    v_w = p.word(v)
    one, two = p.word(1), p.word(2)
    resp = p.word(0)
    wq_a = p.add_wq(2, managed=True, ordering=isa.ORD_DOORBELL,
                    initial_enable=0)
    wq_b = p.add_wq(2, managed=True, ordering=isa.ORD_DOORBELL,
                    initial_enable=0)
    wq_a.write(src=one, dst=resp)
    wq_b.write(src=two, dst=resp)
    mod = p.add_wq(2, managed=True, ordering=isa.ORD_DOORBELL,
                   initial_enable=0)
    ctl = p.add_wq(10, managed=True, ordering=isa.ORD_DOORBELL,
                   initial_enable=99)

    def load(a_addr, b_addr):
        ctl.write(src=v_w, dst=a_addr)
        ctl.write(src=v_w, dst=b_addr)

    constructs.emit_enable_branch(
        ctl, mod, threshold=threshold, then_wq=wq_a.index, then_upto=2,
        else_wq=wq_b.index, else_upto=2, load=load)
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, 64)
    return int(out.mem[resp])


@pytest.mark.parametrize("v,thr,want", [
    (0, 0, 1), (1, 0, 2), (3, 7, 1), (7, 7, 1), (8, 7, 2),
    (0xFFFFFE, 0xFFFFFE, 1), (0xFFFFFD, 3, 2)])
def test_enable_branch_selects_exactly_one_wq(v, thr, want):
    assert _build_branch(v, thr) == want


@settings(max_examples=25, deadline=None)
@given(v=st.integers(0, isa.ID_MASK - 1),
       thr=st.integers(0, isa.ID_MASK - 1))
def test_enable_branch_matches_python(v, thr):
    assert _build_branch(v, thr) == (1 if v <= thr else 2)


# --- displace-move (one hopscotch bubble step) --------------------------------

def test_displace_move_moves_vacates_and_zeroes():
    """One move step over the shared [key, pad, val_ptr] row layout:
    value row copied, key moved, mover CASed to EMPTY, stale row zeroed,
    carries advanced, next WQ released."""
    V, BW = 2, 3
    p = assembler.Program(1024)
    status = p.word(0)
    vals = p.alloc(4 * V, [11, 12, 21, 22, 31, 32, 0, 0], "vals")
    tbl_init = []
    for b, key in enumerate([101, 102, 103, 0]):
        tbl_init += [key, b, vals + b * V]
    table = p.alloc(4 * BW, tbl_init, "table")
    zeros = p.alloc(V, [0] * V)
    cand_w = p.word(table + 1 * BW)     # move bucket 1 ...
    free_w = p.word(table + 3 * BW)     # ... into (empty) bucket 3
    dist_w = p.word(5)
    nxt = p.add_wq(2, managed=True, ordering=isa.ORD_DOORBELL,
                   initial_enable=0)
    done = p.word(0)
    nxt.write_imm(dst=done, value=77)
    ctl = p.add_wq(24, managed=True, ordering=isa.ORD_DOORBELL,
                   initial_enable=99)
    refs = constructs.emit_displace_move(
        ctl, cand_w=cand_w, free_w=free_w, dist_w=dist_w, back=2,
        val_len=V, zeros=zeros, status_addr=status, status_val=4,
        next_wq=nxt.index, next_upto=2)
    spec, st0 = p.finalize()
    out = machine.run(spec, st0, 128)
    mem = np.asarray(out.mem)
    # key + value moved into the free bucket
    assert mem[table + 3 * BW] == 102
    assert mem[vals + 3 * V: vals + 3 * V + V].tolist() == [21, 22]
    # mover vacated, its value row zeroed
    assert mem[table + 1 * BW] == 0
    assert mem[vals + 1 * V: vals + 1 * V + V].tolist() == [0, 0]
    # other buckets untouched
    assert mem[table] == 101 and mem[table + 2 * BW] == 103
    assert mem[vals: vals + V].tolist() == [11, 12]
    # carries advanced, status recorded, next stage released
    assert mem[cand_w] == mem[free_w] == table + 1 * BW
    assert mem[dist_w] == 3
    assert mem[status] == 4
    assert mem[done] == 77
    assert refs.vacate.wq == ctl.index


def test_displace_move_vacate_cas_guards_raced_mover():
    """The vacate CAS re-reads its comparand from the bucket — if the
    resident changed under us the CAS must lose rather than clobber.
    (Single-writer serialization makes this unreachable in the store;
    the construct still guards it.)"""
    V, BW = 1, 3
    p = assembler.Program(512)
    status = p.word(0)
    vals = p.alloc(2 * V, [5, 0])
    table = p.alloc(2 * BW, [9, 0, vals, 0, 1, vals + 1])
    zeros = p.alloc(V, [0])
    cand_w = p.word(table)
    free_w = p.word(table + BW)
    dist_w = p.word(4)
    nxt = p.add_wq(1, managed=True, ordering=isa.ORD_DOORBELL,
                   initial_enable=0)
    nxt.noop()
    ctl = p.add_wq(24, managed=True, ordering=isa.ORD_DOORBELL,
                   initial_enable=99)
    constructs.emit_displace_move(
        ctl, cand_w=cand_w, free_w=free_w, dist_w=dist_w, back=1,
        val_len=V, zeros=zeros, status_addr=status, status_val=4,
        next_wq=nxt.index, next_upto=1)
    # sabotage: swap the resident key after build, before execution —
    # the comparand re-read makes the CAS observe the *new* key, so the
    # vacate still applies to what it read; emulate a racing writer by
    # changing the key between the comparand READ and the CAS instead:
    # overwrite the CAS's patched comparand post-hoc via a stale opa.
    spec, st0 = p.finalize()
    # run up to just after the comparand READ (12 WRs), then mutate
    s = st0
    for _ in range(13):
        s = machine.step(spec, s)
    s = s._replace(mem=s.mem.at[table].set(777))    # racing writer
    out = machine.run(spec, s, 128)
    mem = np.asarray(out.mem)
    # the CAS compared the *old* key against the new resident: no vacate
    assert mem[table] == 777


# --- bucket-vacate (the migrator's tail) --------------------------------------

def _build_vacate(bucket_keys, bucket_rows, target, *, repeats=1, V=2):
    """One doorbell-ordered ctl WQ running ``repeats`` back-to-back
    bucket-vacates of ``table[target]``; returns (spec, st0, probes)."""
    from repro.core import assembler
    BW = 3
    p = assembler.Program(1024)
    n = len(bucket_keys)
    flat = [w for row in bucket_rows for w in row]
    vals = p.alloc(n * V, flat, "vals")
    tbl_init = []
    for b, key in enumerate(bucket_keys):
        tbl_init += [key, b, vals + b * V]
    table = p.alloc(n * BW, tbl_init, "table")
    zeros = p.alloc(V, [0] * V)
    bucket_w = p.word(table + target * BW)
    ctl = p.add_wq(8 * repeats + 2, managed=True,
                   ordering=isa.ORD_DOORBELL, initial_enable=99)
    for _ in range(repeats):
        constructs.emit_bucket_vacate(ctl, bucket_w=bucket_w, val_len=V,
                                      zeros=zeros)
    spec, st0 = p.finalize()
    return spec, st0, (table, vals, BW, V, n)


def _vacate_outcome(spec, st0, backend, max_steps=64):
    from repro.core.engine import ChainEngine
    if backend == "interp":
        return np.asarray(machine.run(spec, st0, max_steps).mem)
    eng = ChainEngine.for_spec(spec, backend)
    batch = jax.tree_util.tree_map(lambda a: jnp.stack([a]), st0)
    return np.asarray(eng.run_batch(batch, max_steps).mem[0])


@pytest.mark.parametrize("backend", ["interp", "pallas-interpret"])
def test_bucket_vacate_already_empty_is_noop(backend):
    """Vacating an EMPTY bucket must leave keys AND value rows untouched:
    the CAS trivially retires 0 -> 0 and the row zeroing rewrites an
    already-zero row (the re-driven-lap idempotency recovery relies on)."""
    keys = [101, 0, 103]
    rows = [[11, 12], [0, 0], [31, 32]]
    spec, st0, (table, vals, BW, V, n) = _build_vacate(keys, rows, target=1)
    mem = _vacate_outcome(spec, st0, backend)
    for b in range(n):
        assert mem[table + b * BW] == keys[b], backend
        assert mem[vals + b * V: vals + (b + 1) * V].tolist() == rows[b]


@pytest.mark.parametrize("backend", ["interp", "pallas-interpret"])
def test_bucket_vacate_double_execution_idempotent(backend):
    """Two back-to-back vacates of a live bucket == one: the second pass
    lands on the EMPTY bucket and is a no-op on keys and value rows."""
    keys = [101, 102, 103]
    rows = [[11, 12], [21, 22], [31, 32]]
    once = _build_vacate(keys, rows, target=1, repeats=1)
    twice = _build_vacate(keys, rows, target=1, repeats=2)
    mem1 = _vacate_outcome(*once[:2], backend)
    mem2 = _vacate_outcome(*twice[:2], backend, max_steps=128)
    table, vals, BW, V, n = once[2]
    # the vacate itself: key retired, row zeroed, neighbours untouched
    assert mem1[table + 1 * BW] == 0
    assert mem1[vals + V: vals + 2 * V].tolist() == [0] * V
    assert mem1[table] == 101 and mem1[table + 2 * BW] == 103
    # second execution changed nothing in the data regions
    t2, v2, *_ = twice[2]
    for b in range(n):
        assert mem2[t2 + b * BW] == mem1[table + b * BW], backend
        np.testing.assert_array_equal(mem2[v2 + b * V: v2 + (b + 1) * V],
                                      mem1[vals + b * V: vals + (b + 1) * V])


@pytest.mark.parametrize("backend", ["interp", "pallas-interpret"])
def test_bucket_vacate_interp_pallas_parity(backend):
    """Both backends agree word-for-word on the whole image (not just the
    data regions) for the empty-bucket no-op run."""
    keys = [7, 0]
    rows = [[70, 71], [0, 0]]
    spec, st0, _ = _build_vacate(keys, rows, target=1)
    ref = _vacate_outcome(spec, st0, "interp")
    got = _vacate_outcome(spec, st0, backend)
    np.testing.assert_array_equal(got, ref)


def test_enable_branch_rejects_id_mask_threshold():
    """threshold+1 must stay inside the 24-bit id space: at ID_MASK the
    packed else-comparand would wrap to 0 and BOTH arms could convert."""
    p = assembler.Program(512)
    mod = p.add_wq(2, managed=True, ordering=isa.ORD_DOORBELL,
                   initial_enable=0)
    ctl = p.add_wq(10, managed=True, ordering=isa.ORD_DOORBELL,
                   initial_enable=99)
    with pytest.raises(ValueError, match="threshold"):
        constructs.emit_enable_branch(
            ctl, mod, threshold=isa.ID_MASK, then_wq=0, then_upto=1,
            else_wq=0, else_upto=1, load=lambda a, b: None)
