"""The chain-offloaded hopscotch displacement: the displacer program vs
the bounded `set_full` host oracle, the sharded_set escalation stage,
and the completed §5.6 story (every SET path serves with the driver
dead).  Also the writer/oracle parity bugfixes that ride along: zero-
filled value tails on shrink updates and zeroed vacated value rows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st
from jax.sharding import Mesh

from repro.core import programs
from repro.kvstore import hopscotch, store
from repro.rdma import failure

NB, H, S, M, V = 64, 4, 8, 4, 2


def _keys_with_home(bucket, count, n_buckets=NB, start=1, n_shards=None):
    return store.keys_homed_at(bucket, count, n_buckets, start=start,
                               n_shards=n_shards)


def test_status_codes_match_across_layers():
    assert hopscotch.SET_DISPLACED == programs.SET_DISPLACED
    assert hopscotch.SET_NEEDS_RESIZE == programs.SET_NEEDS_RESIZE


def test_bucket_home_matches_kvstore_hash():
    """core.programs derives per-bucket home distances with its own copy
    of the multiplicative hash (core must not import kvstore) — the two
    must stay numerically identical."""
    ks = jnp.asarray([1, 2, 12345, 0xFFFFFF, 999983], jnp.int32)
    for n in (7, 64, 128, 1000):
        np.testing.assert_array_equal(
            np.asarray(programs.bucket_home(ks, n)),
            np.asarray(hopscotch.bucket_of(ks, n)))


# --- the displacer program vs the bounded host oracle -------------------------

@pytest.fixture(scope="module")
def displacer():
    return programs.build_hopscotch_displacer(NB, V, H, S, M)


def _run_one(disp, table, key, value, max_steps=4096):
    """One request through the chain; returns (status, keys, vals)."""
    row = np.zeros(V, np.int32)
    row[:len(value)] = value
    keys0, vals0 = table.as_device()
    pay = disp.device_payloads(
        jnp.asarray([key], jnp.int32),
        hopscotch.bucket_of(jnp.asarray([key], jnp.int32), NB),
        jnp.asarray([row], jnp.int32))
    return disp.run_one(keys0, vals0, pay[0], max_steps)


def _assert_matches_oracle(disp, table, key, value, want_status):
    ref = hopscotch.HopscotchTable(table.keys.copy(), table.values.copy(),
                                  H)
    ref_status = ref.set_full(key, value, disp.max_search, disp.max_moves)
    st_, nk, nv = _run_one(disp, table, key, value)
    assert int(st_) == ref_status == want_status
    np.testing.assert_array_equal(np.asarray(nk), ref.keys)
    np.testing.assert_array_equal(np.asarray(nv), ref.values)
    return np.asarray(nk), np.asarray(nv)


def _staggered_full_neighborhood(home):
    """Fill [home, home+H) with keys homed *at* their own bucket (pad 0
    each), so the bubble can move any of them one window forward."""
    t = hopscotch.make_table(NB, V, neighborhood=H)
    for d in range(H):
        k = _keys_with_home((home + d) % NB, 1, start=200 + 97 * d)[0]
        assert t.insert(k, [k % 7, k % 11])
    return t


def test_displacer_one_move_bit_exact(displacer):
    t = _staggered_full_neighborhood(10)
    z = _keys_with_home(10, 1, start=50000)[0]
    nk, nv = _assert_matches_oracle(displacer, t, z, [9, 9],
                                    hopscotch.SET_DISPLACED)
    f, v = hopscotch.lookup(jnp.asarray(nk), jnp.asarray(nv),
                            jnp.asarray([z], jnp.int32), H)
    assert bool(f[0]) and v[0].tolist() == [9, 9]
    # vacated buckets must not leak value words (the zero-row bugfix)
    assert (nv[nk == hopscotch.EMPTY] == 0).all()


def test_displacer_wraparound_window(displacer):
    """Home near the end of the table: the unwrapped mirror rows carry
    the window across the wrap."""
    t = _staggered_full_neighborhood(NB - 2)
    z = _keys_with_home(NB - 2, 1, start=60000)[0]
    _assert_matches_oracle(displacer, t, z, [8, 8],
                           hopscotch.SET_DISPLACED)


def test_displacer_multi_move_ladder(displacer):
    """A pad-2 ladder permits only back=1 moves: the bubble must take
    several laps, each choosing the same window offset."""
    t = hopscotch.make_table(NB, V, neighborhood=H)
    home = 10
    for pos in range(home, home + 6):
        k = _keys_with_home((pos - 2) % NB, 1, start=300 + 13 * pos)[0]
        t.keys[pos] = k
        t.values[pos] = [k % 7, k % 11]
    z = _keys_with_home(home, 1, start=70000)[0]
    nk, nv = _assert_matches_oracle(displacer, t, z, [3, 4],
                                    hopscotch.SET_DISPLACED)
    assert (nv[nk == hopscotch.EMPTY] == 0).all()


def test_displacer_update_and_plain_insert(displacer):
    t = _staggered_full_neighborhood(10)
    upd = int(t.keys[11])
    _assert_matches_oracle(displacer, t, upd, [1], hopscotch.SET_UPDATED)
    t2 = hopscotch.make_table(NB, V, neighborhood=H)
    k0 = _keys_with_home(10, 1)[0]
    assert t2.insert(k0, [5, 5])
    z = _keys_with_home(10, 1, start=90000)[0]
    _assert_matches_oracle(displacer, t2, z, [6, 6],
                           hopscotch.SET_INSERTED)


def test_displacer_stuck_window_needs_resize(displacer):
    """Keys homed at the requester's own bucket fill the neighborhood;
    nothing in any window can move forward — both the chain and the
    bounded oracle answer SET_NEEDS_RESIZE and leave the table
    bit-identical (no partial moves)."""
    t = hopscotch.make_table(NB, V, neighborhood=H)
    cluster = _keys_with_home(10, H + 1)
    for k in cluster[:H]:
        assert t.insert(k, [k % 7, k % 11])
    # occupy the next buckets with immovable (pad-0) residents so the
    # first window contains a movable key but later windows do not
    for d in range(H, H + 2):
        k = _keys_with_home((10 + d) % NB, 1, start=500 + d)[0]
        assert t.insert(k, [k % 7, k % 11])
    keys_before = t.keys.copy()
    vals_before = t.values.copy()
    _assert_matches_oracle(displacer, t, cluster[H], [1, 2],
                           hopscotch.SET_NEEDS_RESIZE)
    np.testing.assert_array_equal(t.keys, keys_before)
    np.testing.assert_array_equal(t.values, vals_before)


def test_displacer_move_budget_honored():
    """max_moves=1 on a ladder that needs several laps: needs-resize,
    with the table untouched on both sides."""
    d1 = programs.build_hopscotch_displacer(NB, V, H, S, 1)
    t = hopscotch.make_table(NB, V, neighborhood=H)
    home = 10
    for pos in range(home, home + 6):
        k = _keys_with_home((pos - 2) % NB, 1, start=300 + 13 * pos)[0]
        t.keys[pos] = k
        t.values[pos] = [k % 7, k % 11]
    z = _keys_with_home(home, 1, start=70000)[0]
    _assert_matches_oracle(d1, t, z, [3, 4], hopscotch.SET_NEEDS_RESIZE)


def test_displacer_search_window_honored(displacer):
    """No EMPTY bucket within max_search probes of home: needs-resize."""
    t = hopscotch.make_table(NB, V, neighborhood=H)
    home = 20
    for pos in range(home, home + S):
        k = _keys_with_home(pos % NB, 1, start=400 + 17 * pos)[0]
        t.keys[pos % NB] = k
        t.values[pos % NB] = [k % 7, k % 11]
    z = _keys_with_home(home, 1, start=80000)[0]
    _assert_matches_oracle(displacer, t, z, [2, 2],
                           hopscotch.SET_NEEDS_RESIZE)


def test_displacer_zero_padded_request_is_inert(displacer):
    """A transport padding slot (all-zero payload) quiesces against the
    null guard: status 0, arrays untouched."""
    t = _staggered_full_neighborhood(10)
    keys0, vals0 = t.as_device()
    st_, nk, nv = displacer.run_one(keys0, vals0,
                                    jnp.zeros(V + 2, jnp.int32), 4096)
    assert int(st_) == 0
    np.testing.assert_array_equal(np.asarray(nk), t.keys)
    np.testing.assert_array_equal(np.asarray(nv), t.values)


def test_displacer_build_bounds():
    with pytest.raises(ValueError, match="neighborhood"):
        programs.build_hopscotch_displacer(NB, V, 1, S, M)
    with pytest.raises(ValueError, match="max_search"):
        programs.build_hopscotch_displacer(NB, V, H, NB + 1, M)
    with pytest.raises(ValueError, match="max_moves"):
        programs.build_hopscotch_displacer(NB, V, H, S, 0)
    with pytest.raises(ValueError, match="request budget"):
        programs.build_hopscotch_displacer(NB, 15, H, S, M)


# --- the sharded_set escalation stage -----------------------------------------

@pytest.fixture(scope="module")
def mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("kv",))


def test_sharded_set_escalates_displacement_bit_exact(mesh1):
    """A mixed batch (update, inserts, a displacement-requiring insert,
    a duplicate of it that must become an update): the two-stage chain
    pipeline matches the two-pass host oracle bit-for-bit, and the new
    values are visible on every get path."""
    nb = 128
    kv = store.ShardedKV.build(1, nb, V)
    home = 40
    staggered = [_keys_with_home((home + d) % nb, 1, n_buckets=nb,
                                 start=200 + 97 * d, n_shards=1)[0]
                 for d in range(8)]
    for k in staggered:
        kv.set(k, [k % 7, k % 11])
    dk, dv = kv.device_arrays()
    z = _keys_with_home(home, 1, n_buckets=nb, start=50000, n_shards=1)[0]
    sk = np.asarray([staggered[3], z, 77001, z], np.int32)
    sv = np.stack([sk % 61, sk % 53], axis=1).astype(np.int32)
    res, nk, nv = store.sharded_set(mesh1, "kv", dk, dv,
                                    jnp.asarray(sk[None]),
                                    jnp.asarray(sv[None]))
    ref = hopscotch.HopscotchTable(kv.tables[0].keys.copy(),
                                   kv.tables[0].values.copy(), 8)
    ref_st = hopscotch.insert_many_displaced(ref, sk, sv)
    np.testing.assert_array_equal(np.asarray(res.status[0]), ref_st)
    assert int(res.status[0][1]) == programs.SET_DISPLACED
    assert int(res.status[0][3]) == programs.SET_UPDATED  # dup -> update
    assert bool(np.asarray(res.applied[0]).all())
    assert bool(np.asarray(res.ok[0]).all())
    np.testing.assert_array_equal(np.asarray(nk[0]), ref.keys)
    np.testing.assert_array_equal(np.asarray(nv[0]), ref.values)
    q = jnp.asarray(sk[None])
    for m in ("redn", "one_sided", "two_sided"):
        g = store.sharded_get(mesh1, "kv", nk, nv, q, method=m)
        assert np.asarray(g.found[0]).all(), m
        np.testing.assert_array_equal(np.asarray(g.values[0][1]), sv[3])


def test_sharded_set_resize_rows_not_acked(mesh1):
    """A genuinely unplaceable insert (stuck window) reports
    SET_NEEDS_RESIZE, applied=False, and leaves the arrays untouched."""
    nb = 128
    kv = store.ShardedKV.build(1, nb, V)
    cluster = _keys_with_home(7, 9, n_buckets=nb, start=1000, n_shards=1)
    for k in cluster[:8]:
        kv.set(k, [k % 5 + 1, k % 3 + 1])
    dk, dv = kv.device_arrays()
    sk = np.asarray([cluster[8]], np.int32)
    sv = np.asarray([[1, 2]], np.int32)
    res, nk, nv = store.sharded_set(mesh1, "kv", dk, dv,
                                    jnp.asarray(sk[None]),
                                    jnp.asarray(sv[None]))
    assert int(res.status[0][0]) == programs.SET_NEEDS_RESIZE
    assert not bool(np.asarray(res.applied[0]).any())
    assert bool(np.asarray(res.ok[0]).all())   # answered, not dropped
    np.testing.assert_array_equal(np.asarray(nk), np.asarray(dk))
    np.testing.assert_array_equal(np.asarray(nv), np.asarray(dv))


# --- oracle parity under load (the hypothesis sweep) --------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 31 - 1))
def test_displacer_oracle_parity_random_high_load(seed):
    """Random SET batches against a table at load factor ~0.85+, applied
    through the writer + displacer pipeline, replayed on the bounded host
    oracle; interleaved gets check the store serves exactly the oracle's
    table state."""
    _random_parity_round(seed)


def test_displacer_oracle_parity_seeded():
    """Deterministic instances of the same property (runs without
    hypothesis)."""
    for seed in (0, 7, 1234):
        _random_parity_round(seed)


def _random_parity_round(seed):
    rng = np.random.RandomState(seed)
    mesh = Mesh(np.array(jax.devices()[:1]), ("kv",))
    t = hopscotch.make_table(NB, V, neighborhood=H)
    # fill to load factor ~0.85 with the bounded host insert
    n_target = int(NB * 0.85)
    k = 1 + int(rng.randint(1 << 20))
    while (t.keys != hopscotch.EMPTY).sum() < n_target:
        t.insert(int(k), [int(k) % 97, int(k) % 89],
                 max_search=S, max_moves=M)
        k += 1 + int(rng.randint(50))
    dk, dv = t.as_device()
    dk, dv = dk[None], dv[None]          # (S=1, B), (S=1, B, V)
    ref = hopscotch.HopscotchTable(t.keys.copy(), t.values.copy(), H)

    for _ in range(2):
        live = t.keys[t.keys != hopscotch.EMPTY]
        upd = rng.choice(live, size=3).astype(np.int64)
        new = 1 + rng.randint(0, 1 << 22, size=3).astype(np.int64)
        sk = np.concatenate([upd, new]).astype(np.int32)
        rng.shuffle(sk)
        sv = np.stack([sk % 251, sk % 241], axis=1).astype(np.int32)
        res, dk, dv = store.sharded_set(
            mesh, "kv", dk, dv, jnp.asarray(sk[None]),
            jnp.asarray(sv[None]), neighborhood=H, max_search=S,
            max_moves=M)
        ref_st = hopscotch.insert_many_displaced(ref, sk, sv, S, M)
        np.testing.assert_array_equal(np.asarray(res.status[0]), ref_st)
        np.testing.assert_array_equal(np.asarray(dk[0]), ref.keys)
        np.testing.assert_array_equal(np.asarray(dv[0]), ref.values)
        # interleaved gets: the chain get serves the oracle's exact state
        q = np.concatenate([sk, [0]]).astype(np.int32)
        g = store.sharded_get(mesh, "kv", dk, dv, jnp.asarray(q[None]),
                              method="redn", neighborhood=H)
        rf, rv = hopscotch.lookup(*ref.as_device(),
                                  jnp.asarray(q, jnp.int32), H)
        np.testing.assert_array_equal(np.asarray(g.found[0]),
                                      np.asarray(rf))
        np.testing.assert_array_equal(np.asarray(g.values[0]),
                                      np.asarray(rv))


# --- satellite: shrink-update parity (stale value tails) ----------------------

def test_update_with_shorter_value_zero_fills_tail(mesh1):
    """Re-setting a key with a shorter value must zero the trailing
    words on *every* path — host set_fast/set_full and the chain writer
    all write full val_words rows now."""
    t = hopscotch.make_table(NB, V, neighborhood=H)
    k = 17
    assert t.insert(k, [7, 8])
    assert t.set_fast(k, [5]) == hopscotch.SET_UPDATED
    np.testing.assert_array_equal(
        t.values[np.where(t.keys == k)[0][0]], [5, 0])

    t2 = hopscotch.make_table(NB, V, neighborhood=H)
    assert t2.insert(k, [7, 8])
    assert t2.set_full(k, [5]) == hopscotch.SET_UPDATED
    np.testing.assert_array_equal(
        t2.values[np.where(t2.keys == k)[0][0]], [5, 0])


def test_chain_vs_insert_many_shrink_update_parity(mesh1):
    """The regression the bug caused: chain writer and host oracle used
    to diverge on an update with a shorter value (the chain writes the
    full zero-padded row; the host left the stale tail)."""
    kv = store.ShardedKV.build(1, 128, V)
    kv.set(23, [7, 8])
    dk, dv = kv.device_arrays()
    sk = np.asarray([23], np.int32)
    sv = np.asarray([[5, 0]], np.int32)      # "shorter" value, zero-padded
    res, nk, nv = store.sharded_set(mesh1, "kv", dk, dv,
                                    jnp.asarray(sk[None]),
                                    jnp.asarray(sv[None]))
    ref = hopscotch.HopscotchTable(kv.tables[0].keys.copy(),
                                   kv.tables[0].values.copy(), 8)
    ref_st = hopscotch.insert_many(ref, sk, [[5]])   # short host-side form
    np.testing.assert_array_equal(np.asarray(res.status[0]), ref_st)
    np.testing.assert_array_equal(np.asarray(nk[0]), ref.keys)
    np.testing.assert_array_equal(np.asarray(nv[0]), ref.values)
    g = store.sharded_get(mesh1, "kv", nk, nv,
                          jnp.asarray(sk[None]), method="redn")
    np.testing.assert_array_equal(np.asarray(g.values[0][0]), [5, 0])


# --- satellite: 24-bit key bound on the batched paths -------------------------

def test_batched_paths_reject_wide_keys(mesh1):
    kv = store.ShardedKV.build(1, 128, V)
    dk, dv = kv.device_arrays()
    wide = jnp.asarray([[0x1000000]], jnp.int32)
    neg = jnp.asarray([[-5]], jnp.int32)
    with pytest.raises(ValueError, match="24-bit"):
        store.sharded_get(mesh1, "kv", dk, dv, wide)
    with pytest.raises(ValueError, match="24-bit"):
        store.sharded_get(mesh1, "kv", dk, dv, neg)
    sv = jnp.zeros((1, 1, V), jnp.int32)
    with pytest.raises(ValueError, match="24-bit"):
        store.sharded_set(mesh1, "kv", dk, dv, wide, sv)
    with pytest.raises(ValueError, match="24-bit"):
        store.sharded_set(mesh1, "kv", dk, dv, neg, sv)


def test_service_batched_paths_reject_wide_keys():
    svc = failure.ShardedKVService.start([(5, [1, 2])])
    with pytest.raises(ValueError, match="24-bit"):
        svc.get_many(np.asarray([1 << 24], np.int64))
    with pytest.raises(ValueError, match="24-bit"):
        svc.set_many(np.asarray([1 << 24], np.int64),
                     np.asarray([[1, 2]], np.int64))
    # in-range keys still served; 0 stays a legal always-miss query
    g = svc.get_many(np.asarray([5, 0], np.int32))
    assert bool(g.found[0][0]) and not bool(g.found[0][1])


# --- satellite: serving caches keyed on mesh geometry -------------------------

def test_same_geometry_meshes_share_one_compiled_step():
    """Two same-geometry meshes must hit one cache entry — and the cache
    key must be a plain tuple of the geometry (axis names, shape, device
    ids), never the Mesh object, so the serving cache cannot grow with
    (or pin) per-call Mesh/device handles beyond one closure per
    distinct geometry."""
    m1 = Mesh(np.array(jax.devices()[:1]), ("kv",))
    m2 = Mesh(np.array(jax.devices()[:1]), ("kv",))
    g1 = store._mapped_get(m1, "kv", "redn", 1, 4, 8, 2)
    n_entries = len(store._MAPPED_CACHE)
    g2 = store._mapped_get(m2, "kv", "redn", 1, 4, 8, 2)
    assert g1 is g2
    assert len(store._MAPPED_CACHE) == n_entries   # no second entry
    s1 = store._mapped_set(m1, "kv", 1, 4, 8, 2, 512, 16, 8)
    s2 = store._mapped_set(m2, "kv", 1, 4, 8, 2, 512, 16, 8)
    assert s1 is s2
    for key in store._MAPPED_CACHE:
        assert not any(isinstance(part, Mesh) for part in key)
        hash(key)                                  # geometry is hashable
    # and the shared step serves both meshes' calls identically
    kv = store.ShardedKV.build(1, 128, 2)
    kv.set(9, [3, 4])
    dk, dv = kv.device_arrays()
    q = jnp.asarray([[9, 10, 0, 9]], jnp.int32)
    r1 = store.sharded_get(m1, "kv", dk, dv, q, capacity=4)
    r2 = store.sharded_get(m2, "kv", dk, dv, q, capacity=4)
    np.testing.assert_array_equal(np.asarray(r1.found),
                                  np.asarray(r2.found))
    np.testing.assert_array_equal(np.asarray(r1.values),
                                  np.asarray(r2.values))


def test_escalation_fuel_covers_large_unrolls(mesh1):
    """Regression: the displacer stage's step budget must scale with the
    unroll (`HopscotchShardWriter.fuel`), not a fixed multiple of
    max_steps — a 16-move ladder under max_steps=256 used to exhaust
    fuel mid-bubble and misreport a placeable key as needs-resize."""
    nb, h = 128, 8
    s_bound, m_bound = 24, 16
    t = hopscotch.make_table(nb, V, neighborhood=h)
    home = 30
    for pos in range(home, home + 23):       # pad-6 ladder: back=1 only
        k = _keys_with_home((pos - 6) % nb, 1, n_buckets=nb,
                            start=500 + 29 * pos, n_shards=1)[0]
        t.keys[pos % nb] = k
        t.values[pos % nb] = [k % 7, k % 11]
    z = _keys_with_home(home, 1, n_buckets=nb, start=60000, n_shards=1)[0]
    disp = programs.build_hopscotch_displacer(nb, V, h, s_bound, m_bound)
    assert disp.fuel > 8 * 256               # the old heuristic budget
    dk, dv = t.as_device()
    sk = np.asarray([z], np.int32)
    sv = np.asarray([[5, 6]], np.int32)
    res, nk, nv = store.sharded_set(
        mesh1, "kv", dk[None], dv[None], jnp.asarray(sk[None]),
        jnp.asarray(sv[None]), neighborhood=h, max_steps=256,
        max_search=s_bound, max_moves=m_bound)
    ref = hopscotch.HopscotchTable(t.keys.copy(), t.values.copy(), h)
    assert ref.set_full(z, [5, 6], s_bound, m_bound) \
        == hopscotch.SET_DISPLACED
    assert int(res.status[0][0]) == programs.SET_DISPLACED
    np.testing.assert_array_equal(np.asarray(nk[0]), ref.keys)
    np.testing.assert_array_equal(np.asarray(nv[0]), ref.values)


def test_live_masked_rows_may_hold_sentinel_keys(mesh1):
    """Rows an admission stage masked dead (live=False) are never
    dispatched, so out-of-range sentinels there must not raise — only
    live rows are validated."""
    kv = store.ShardedKV.build(1, 128, V)
    kv.set(9, [3, 4])
    dk, dv = kv.device_arrays()
    q = jnp.asarray([[9, -1]], jnp.int32)          # -1 sentinel, masked
    live = jnp.asarray([[True, False]])
    r = store.sharded_get(mesh1, "kv", dk, dv, q, live=live)
    assert bool(r.found[0][0]) and not bool(r.ok[0][1])
    with pytest.raises(ValueError, match="24-bit"):
        store.sharded_get(mesh1, "kv", dk, dv, q)  # unmasked: rejected


def test_sharded_set_on_tiny_shard_serves_writer_only(mesh1):
    """A shard smaller than the neighborhood cannot build a displacer
    (its unroll needs >= H probes) — the set path must still serve, with
    escalated rows resolving to SET_NEEDS_RESIZE exactly as the bounded
    oracle answers (a full wrap-covered table has nothing to bubble)."""
    nb = 4
    kv = store.ShardedKV.build(1, nb, V)
    dk, dv = kv.device_arrays()
    sk = np.asarray([11, 12, 13, 14, 15], np.int32)
    sv = np.stack([sk % 7, sk % 5], axis=1).astype(np.int32)
    res, nk, nv = store.sharded_set(mesh1, "kv", dk, dv,
                                    jnp.asarray(sk[None]),
                                    jnp.asarray(sv[None]))
    ref = hopscotch.HopscotchTable(kv.tables[0].keys.copy(),
                                   kv.tables[0].values.copy(), 8)
    ref_st = hopscotch.insert_many_displaced(ref, sk, sv,
                                             max_search=nb)
    np.testing.assert_array_equal(np.asarray(res.status[0]), ref_st)
    # 4 buckets absorb 4 inserts; the 5th is a genuine needs-resize
    assert sorted(np.asarray(res.status[0]).tolist()) == [2, 2, 2, 2, 5]
    np.testing.assert_array_equal(np.asarray(nk[0]), ref.keys)
    np.testing.assert_array_equal(np.asarray(nv[0]), ref.values)


def test_service_start_rejects_overfull_bootstrap():
    """Bootstrap items the bounded host insert cannot place must raise,
    not silently vanish into a later unexplained miss."""
    cl = _keys_with_home(3, 10, n_buckets=16, start=100)
    items = [(k, [1, 2]) for k in cl]
    with pytest.raises(ValueError, match="resize"):
        failure.ShardedKVService.start(items, buckets_per_shard=16)


def test_sharded_set_neighborhood_one_still_serves(mesh1):
    """H=1 (a degenerate single-bucket neighborhood) cannot build a
    displacer — its bubble window is empty — but the set path must keep
    serving: updates/inserts via the writer, escalated rows resolved to
    SET_NEEDS_RESIZE exactly as the bounded oracle answers."""
    nb = 64
    kv = store.ShardedKV.build(1, nb, V, neighborhood=1)
    dk, dv = kv.device_arrays()
    a = _keys_with_home(5, 1, n_buckets=nb)[0]
    b = _keys_with_home(5, 2, n_buckets=nb, start=a + 1)[1]
    sk = np.asarray([a, a, b], np.int32)   # insert, update, bucket-full
    sv = np.stack([sk % 7 + 1, sk % 5 + 1], axis=1).astype(np.int32)
    res, nk, nv = store.sharded_set(mesh1, "kv", dk, dv,
                                    jnp.asarray(sk[None]),
                                    jnp.asarray(sv[None]), neighborhood=1)
    ref = hopscotch.HopscotchTable(kv.tables[0].keys.copy(),
                                   kv.tables[0].values.copy(), 1)
    ref_st = hopscotch.insert_many_displaced(ref, sk, sv)
    np.testing.assert_array_equal(np.asarray(res.status[0]), ref_st)
    np.testing.assert_array_equal(
        np.asarray(res.status[0]),
        [programs.SET_INSERTED, programs.SET_UPDATED,
         programs.SET_NEEDS_RESIZE])
    np.testing.assert_array_equal(np.asarray(nk[0]), ref.keys)
    np.testing.assert_array_equal(np.asarray(nv[0]), ref.values)
