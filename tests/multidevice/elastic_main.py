"""Subprocess body: elastic scaling — checkpoint on a (4 data, 2 model)
mesh, restore resharded onto (2 data, 2 model), keep training, and match a
never-resharded run bit-for-bit."""
import os
import tempfile

assert "--xla_force_host_platform_device_count=8" in os.environ.get(
    "XLA_FLAGS", "")

import jax                                      # noqa: E402
import jax.numpy as jnp                         # noqa: E402
import numpy as np                              # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import registry              # noqa: E402
from repro.data.pipeline import TokenPipeline   # noqa: E402
from repro.distributed import fault, sharding as shrules  # noqa: E402
from repro.distributed import specs as specs_lib  # noqa: E402
from repro.models import model as M             # noqa: E402
from repro.train import checkpoint as ckpt_lib  # noqa: E402
from repro.train import loop as loop_lib        # noqa: E402
from repro.train import optimizer as opt_lib    # noqa: E402

cfg = registry.smoke_config("smollm-135m")
ocfg = opt_lib.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=20)
pipe = TokenPipeline(cfg.vocab_size, 32, 8, seed=11)


def batch_fn(i):
    return {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}


def sharded_setup(mesh):
    with shrules.use_mesh(mesh) as rules:
        aparams = M.abstract_params(cfg)
        p_sh = specs_lib.to_shardings(
            specs_lib.param_specs(aparams, mesh, rules), mesh)
        step = jax.jit(loop_lib.make_train_step(cfg, ocfg))
    return p_sh, step, rules


params0 = M.init_params(jax.random.PRNGKey(0), cfg)
opt0 = opt_lib.init(params0)

# plan check
plan = fault.remesh_plan({"data": 4, "model": 2}, {"data": 2, "model": 2},
                         global_batch=8)
assert plan["batch_ok"]

# phase 1: big mesh, 5 steps, checkpoint
mesh_a = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
p_sh_a, step_a, rules_a = sharded_setup(mesh_a)
p = jax.device_put(params0, p_sh_a)
o = opt0
for i in range(5):
    p, o, m = step_a(p, o, batch_fn(i))
ckdir = tempfile.mkdtemp()
ckpt_lib.save(ckdir, 5, {"params": p, "opt": o})

# phase 2: SHRUNK mesh (node loss), restore resharded, 5 more steps
mesh_b = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
p_sh_b, step_b, rules_b = sharded_setup(mesh_b)
trees = ckpt_lib.restore(ckdir, 5,
                         {"params": jax.eval_shape(lambda: params0),
                          "opt": jax.eval_shape(lambda: opt0)},
                         shardings={"params": p_sh_b, "opt": None})
p2, o2 = trees["params"], trees["opt"]
# params really live on the small mesh now
leaf = jax.tree_util.tree_leaves(p2)[0]
assert leaf.sharding.mesh.shape == {"data": 2, "model": 2}, leaf.sharding
for i in range(5, 10):
    p2, o2, m2 = step_b(p2, o2, batch_fn(i))

# reference: uninterrupted single-device run
pr, orr = params0, opt0
step_r = jax.jit(loop_lib.make_train_step(cfg, ocfg))
for i in range(10):
    pr, orr, mr = step_r(pr, orr, batch_fn(i))

for a, b in zip(jax.tree_util.tree_leaves(p2),
                jax.tree_util.tree_leaves(pr)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-4, rtol=1e-4)
print("ELASTIC_OK")
