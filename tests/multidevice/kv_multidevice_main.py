"""Subprocess body: sharded KV get paths on an 8-device host mesh.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the parent
test sets it; NEVER set this in conftest — smoke tests must see 1 device).
"""
import os

assert "--xla_force_host_platform_device_count=8" in os.environ.get(
    "XLA_FLAGS", ""), "parent must set XLA_FLAGS"

import jax                                      # noqa: E402
import jax.numpy as jnp                         # noqa: E402
import numpy as np                              # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.kvstore import store                 # noqa: E402

assert len(jax.devices()) == 8, jax.devices()

S = 8
kv = store.ShardedKV.build(n_shards=S, buckets_per_shard=64, val_words=2)
rng = np.random.RandomState(0)
keys = rng.choice(np.arange(1, 1 << 16), size=120, replace=False)
for k in keys:
    kv.set(int(k), [int(k) % 251, int(k) % 241])

mesh = Mesh(np.array(jax.devices()).reshape(S), ("kv",))
dk, dv = kv.device_arrays()
dk = jax.device_put(dk, NamedSharding(mesh, P("kv")))
dv = jax.device_put(dv, NamedSharding(mesh, P("kv")))

B = 16
probe = rng.choice(keys, size=S * B).astype(np.int32)
probe[::13] = 1 << 20          # sprinkle misses
q = jax.device_put(jnp.asarray(probe.reshape(S, B)),
                   NamedSharding(mesh, P("kv")))

rfound, rvals = store.reference_get(kv, probe)
for method in ("redn", "one_sided", "two_sided"):
    res = store.sharded_get(mesh, "kv", dk, dv, q, method=method)
    np.testing.assert_array_equal(
        np.asarray(res.found).reshape(-1), rfound, err_msg=method)
    np.testing.assert_array_equal(
        np.asarray(res.values).reshape(-1, 2), rvals, err_msg=method)
    assert bool(jnp.all(res.ok))
    assert int(jnp.sum(res.dropped)) == 0
    print(f"OK {method}: cross-shard routing matches reference")

print("MULTIDEVICE_KV_OK")
