"""Subprocess body: gpipe over a 4-stage pipeline axis == sequential apply,
and its gradients flow through the ppermute ring."""
import os

assert "--xla_force_host_platform_device_count=8" in os.environ.get(
    "XLA_FLAGS", "")

import jax                                      # noqa: E402
import jax.numpy as jnp                         # noqa: E402
import numpy as np                              # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.distributed import pipeline          # noqa: E402

S = 4            # stages on the 'pod' axis
M = 6            # microbatches
B, D = 2, 16

mesh = Mesh(np.array(jax.devices()[:S]).reshape(S), ("pod",))
rng = np.random.RandomState(0)
w_all = jnp.asarray(rng.randn(S, D, D) * 0.3, jnp.float32)   # stage params
x = jnp.asarray(rng.randn(M, B, D), jnp.float32)


def stage_fn(w, h):
    return jnp.tanh(h @ w)


# sequential reference
ref = x
for s in range(S):
    ref = stage_fn(w_all[s], ref.reshape(M * B, D)).reshape(M, B, D)


def run_pipe(w_all, x):
    def body(w_stage, x_mb):
        out = pipeline.gpipe(stage_fn, w_stage[0], x_mb, axis_name="pod",
                             n_stages=S)
        # only the last stage holds real outputs; share them
        out = jax.lax.psum(out, "pod") - (S - 1) * 0.0
        return out

    from repro.compat import shard_map
    f = shard_map(body, mesh=mesh,
                  in_specs=(P("pod"), P()), out_specs=P(),
                  check_vma=False)
    return f(w_all, x)


got = run_pipe(w_all, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
print("forward OK")

# gradients flow through the collective_permute ring
def _seq(w):
    h = x
    for s in range(S):
        h = stage_fn(w[s], h.reshape(M * B, D)).reshape(M, B, D)
    return h


g_pipe = jax.grad(lambda w: jnp.sum(run_pipe(w, x) ** 2))(w_all)
g_ref = jax.grad(lambda w: jnp.sum(_seq(w) ** 2))(w_all)
np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_ref),
                           atol=1e-4, rtol=1e-4)
print("backward OK")
print("PIPELINE_OK")
