"""The chain-offloaded SET path: writer program vs the host insert oracle,
sharded_set through the mesh, cross-path visibility, and the §5.6
driver-dead fast-path set story."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import programs
from repro.core.engine import ChainEngine
from repro.kvstore import hopscotch, store
from repro.rdma import failure

NB = 64


def _keys_with_home(bucket, count, n_buckets=NB, start=1, n_shards=None):
    """Brute-force 24-bit keys whose home bucket is `bucket` (optionally
    pinned to shard 0, for service-level displacement scenarios)."""
    return store.keys_homed_at(bucket, count, n_buckets, start=start,
                               n_shards=n_shards)


def test_set_status_codes_match_across_layers():
    """The chain writer's response codes and the host oracle's constants
    are defined in two modules (core must not import kvstore) — they must
    stay numerically identical."""
    assert hopscotch.SET_UPDATED == programs.SET_UPDATED
    assert hopscotch.SET_INSERTED == programs.SET_INSERTED
    assert (hopscotch.SET_NEEDS_DISPLACEMENT
            == programs.SET_NEEDS_DISPLACEMENT)


# --- the writer program vs the host oracle -----------------------------------

@pytest.fixture(scope="module")
def seeded():
    t = hopscotch.make_table(NB, 2, neighborhood=8)
    for k in range(1, 25):
        assert t.insert(k, [k, k * 2])
    return t


def test_writer_chain_bit_exact_with_insert_oracle(seeded):
    """Updates, in-neighborhood inserts, and repeated writes to the same
    key: statuses and the full (keys, values) arrays match the batched
    host oracle applied in the same order."""
    t = seeded
    keys0, vals0 = t.as_device()
    w = programs.build_hopscotch_writer(NB, 2, 8)
    reqs = np.asarray([5, 70001, 5, 70002, 70001, 19], np.int32)
    vals = np.stack([reqs % 97, reqs % 89], axis=1).astype(np.int32)
    st, nk, nv = w.set_many(keys0, vals0, jnp.asarray(reqs),
                            hopscotch.bucket_of(jnp.asarray(reqs), NB),
                            jnp.asarray(vals))
    ref_t = hopscotch.HopscotchTable(t.keys.copy(), t.values.copy(), 8)
    ref_status = hopscotch.insert_many(ref_t, reqs, vals)
    np.testing.assert_array_equal(np.asarray(st), ref_status)
    np.testing.assert_array_equal(np.asarray(nk), ref_t.keys)
    np.testing.assert_array_equal(np.asarray(nv), ref_t.values)
    assert int(st[0]) == programs.SET_UPDATED
    assert int(st[1]) == programs.SET_INSERTED
    assert int(st[2]) == programs.SET_UPDATED    # second write = update
    assert int(st[4]) == programs.SET_UPDATED    # insert then update


def test_writer_chain_reports_needs_displacement_without_mutation():
    """A neighborhood-full insert answers SET_NEEDS_DISPLACEMENT and
    leaves the table bit-identical — the host slow path's cue; an update
    inside the full neighborhood still works."""
    t = hopscotch.make_table(NB, 2, neighborhood=8)
    cluster = _keys_with_home(7, 9)
    for k in cluster[:8]:
        assert t.insert(k, [k, k + 1])
    keys0, vals0 = t.as_device()
    w = programs.build_hopscotch_writer(NB, 2, 8)
    reqs = np.asarray([cluster[8], cluster[3]], np.int32)
    vals = np.asarray([[1, 2], [77, 78]], np.int32)
    st, nk, nv = w.set_many(keys0, vals0, jnp.asarray(reqs),
                            hopscotch.bucket_of(jnp.asarray(reqs), NB),
                            jnp.asarray(vals))
    ref_status = hopscotch.insert_many(t, reqs, vals)
    np.testing.assert_array_equal(np.asarray(st), ref_status)
    assert int(st[0]) == programs.SET_NEEDS_DISPLACEMENT
    assert int(st[1]) == programs.SET_UPDATED
    np.testing.assert_array_equal(np.asarray(nk), t.keys)
    np.testing.assert_array_equal(np.asarray(nv), t.values)


def test_writer_sequentializes_conflicting_inserts(seeded):
    """Two fresh keys with the same home bucket in one batch must claim
    *different* buckets (request i observes writes 0..i-1)."""
    t = seeded
    keys0, vals0 = t.as_device()
    w = programs.build_hopscotch_writer(NB, 2, 8)
    a, b = _keys_with_home(33, 2, start=100000)
    reqs = np.asarray([a, b], np.int32)
    vals = np.asarray([[1, 1], [2, 2]], np.int32)
    st, nk, nv = w.set_many(keys0, vals0, jnp.asarray(reqs),
                            hopscotch.bucket_of(jnp.asarray(reqs), NB),
                            jnp.asarray(vals))
    assert (np.asarray(st) == programs.SET_INSERTED).all()
    found, got = hopscotch.lookup(nk, nv, jnp.asarray(reqs), 8)
    assert bool(found.all())
    np.testing.assert_array_equal(np.asarray(got), vals)


def test_writer_rejected_on_pallas_backend_interp_serves():
    """The writer is a multi-WQ program: the single-WQ pallas backend must
    reject it explicitly, and the interp fallback must serve it."""
    w = programs.build_hopscotch_writer(32, 2, 4)
    with pytest.raises(ValueError, match="single-WQ"):
        ChainEngine.for_spec(w.spec, "pallas")
    with pytest.raises(ValueError, match="single-WQ"):
        ChainEngine.for_spec(w.spec, "pallas-interpret")
    assert w.engine.backend == "interp"
    zk = jnp.zeros((32,), jnp.int32)
    zv = jnp.zeros((32, 2), jnp.int32)
    st, nk, nv = w.set_many(zk, zv, jnp.asarray([9], jnp.int32),
                            hopscotch.bucket_of(jnp.asarray([9]), 32),
                            jnp.asarray([[4, 5]], jnp.int32))
    assert int(st[0]) == programs.SET_INSERTED
    f, v = hopscotch.lookup(nk, nv, jnp.asarray([9], jnp.int32), 4)
    assert bool(f[0]) and v[0].tolist() == [4, 5]


def test_writer_request_budget_enforced():
    """1 + val_len + neighborhood must fit one 16-word SEND/RECV."""
    with pytest.raises(ValueError):
        programs.build_hopscotch_writer(32, 8, 8)
    programs.build_hopscotch_writer(32, 7, 8)     # the boundary fits


# --- sharded_set through the mesh --------------------------------------------

@pytest.fixture(scope="module")
def mesh_kv():
    kv = store.ShardedKV.build(n_shards=1, buckets_per_shard=128,
                               val_words=2)
    rng = np.random.RandomState(2)
    keys = rng.choice(np.arange(1, 1 << 16), size=48, replace=False)
    for k in keys:
        kv.set(int(k), [int(k) % 251, int(k) % 241])
    mesh = Mesh(np.array(jax.devices()[:1]), ("kv",))
    return kv, keys, mesh


def test_sharded_set_bit_exact_and_visible_on_all_get_paths(mesh_kv):
    """The acceptance scenario: a routed batch of updates + inserts
    executes as writer chains at the owner shard, matches the host oracle
    bit-for-bit, and the new values are visible through redn, one_sided,
    and two_sided gets (update-after-insert included)."""
    kv, keys, mesh = mesh_kv
    dk, dv = kv.device_arrays()
    upd = keys[:5].astype(np.int32)
    new = np.asarray([80001, 80002, 80003], np.int32)
    sk = np.concatenate([upd, new, new[:1]])      # re-set 80001: update
    sv = np.stack([sk % 61, sk % 53], axis=1).astype(np.int32)
    res, nk, nv = store.sharded_set(mesh, "kv", dk, dv,
                                    jnp.asarray(sk[None]),
                                    jnp.asarray(sv[None]))
    assert bool(np.asarray(res.ok).all())
    assert bool(np.asarray(res.applied).all())
    assert int(res.dropped[0]) == 0

    ref_t = hopscotch.HopscotchTable(kv.tables[0].keys.copy(),
                                     kv.tables[0].values.copy(), 8)
    ref_status = hopscotch.insert_many(ref_t, sk, sv)
    np.testing.assert_array_equal(np.asarray(res.status[0]), ref_status)
    np.testing.assert_array_equal(np.asarray(nk[0]), ref_t.keys)
    np.testing.assert_array_equal(np.asarray(nv[0]), ref_t.values)

    probe = np.concatenate([sk[:-1], [0, 99991]]).astype(np.int32)
    q = jnp.asarray(probe[None])
    outs = {}
    for m in ("redn", "one_sided", "two_sided"):
        r = store.sharded_get(mesh, "kv", nk, nv, q, method=m)
        f, v = np.asarray(r.found[0]), np.asarray(r.values[0])
        assert f[:len(sk) - 1].all(), m
        np.testing.assert_array_equal(v[:5], sv[:5])
        np.testing.assert_array_equal(v[5], sv[-1])   # update-after-insert
        assert not f[-2], (m, "query-0 ghost hit")    # never-inserted + 0
        assert not f[-1], m
        outs[m] = (f, v)
    for m in ("one_sided", "two_sided"):
        np.testing.assert_array_equal(outs["redn"][1], outs[m][1])


def test_sharded_set_padding_slots_are_inert(mesh_kv):
    """Key-0 (unused) slots must not occupy dispatch capacity, evict real
    writes, report ok=True, or inflate the drop/defer counters."""
    kv, keys, mesh = mesh_kv
    dk, dv = kv.device_arrays()
    sk = np.asarray([0, 91001], np.int32)     # padding ahead of a real set
    sv = np.asarray([[0, 0], [6, 7]], np.int32)
    res, nk, nv = store.sharded_set(mesh, "kv", dk, dv,
                                    jnp.asarray(sk[None]),
                                    jnp.asarray(sv[None]), capacity=1)
    ok = np.asarray(res.ok[0])
    assert not ok[0] and ok[1]                # real write got the slot
    assert int(res.status[0][1]) == programs.SET_INSERTED
    assert int(res.dropped[0]) == 0 and int(res.deferred[0]) == 0
    f, v = hopscotch.lookup(nk[0], nv[0], jnp.asarray(sk[1:]), 8)
    assert bool(f[0]) and v[0].tolist() == [6, 7]


def test_sharded_set_capacity_drops_are_not_acks(mesh_kv):
    """Over-capacity SETs come back ok=False/applied=False and leave the
    store untouched — a dropped write must never look acknowledged."""
    kv, keys, mesh = mesh_kv
    dk, dv = kv.device_arrays()
    sk = np.asarray([90001, 90002, 90003, 90004], np.int32)
    sv = np.stack([sk % 7, sk % 11], axis=1).astype(np.int32)
    cap = 2
    res, nk, nv = store.sharded_set(mesh, "kv", dk, dv,
                                    jnp.asarray(sk[None]),
                                    jnp.asarray(sv[None]), capacity=cap)
    ok = np.asarray(res.ok[0])
    assert ok.sum() == cap and int(res.dropped[0]) == len(sk) - cap
    assert not np.asarray(res.applied[0])[~ok].any()
    assert (np.asarray(res.status[0])[~ok] == 0).all()
    # only the admitted writes landed
    f, _ = hopscotch.lookup(nk[0], nv[0], jnp.asarray(sk), 8)
    np.testing.assert_array_equal(np.asarray(f), ok)


# --- §5.6: displacement is chain-served too (no host role left) ---------------

def test_service_displacement_serves_with_driver_dead():
    """The acceptance scenario: a neighborhood-full insert — the one SET
    path that used to fall back to the host — completes through the
    displacer chain with the driver crashed, and every key (including
    the displaced one) is served by the chain get path afterwards."""
    nb, home = 128, 40
    staggered = [_keys_with_home((home + d) % nb, 1, n_buckets=nb,
                                 start=200 + 97 * d, n_shards=1)[0]
                 for d in range(8)]
    svc = failure.ShardedKVService.start(
        [(k, [k % 7, k % 11]) for k in staggered])
    # overwrite one value through the chain so any stale host copy would
    # be caught: displacement must move the *device* truth around
    assert svc.set(staggered[2], [42, 43])
    z = _keys_with_home(home, 1, n_buckets=nb, start=50000, n_shards=1)[0]
    svc.crash_host()
    assert not svc.host_alive()
    assert svc.set(z, [9, 9])          # displacement, host driver dead
    r = svc.get_many(np.asarray(staggered + [z], np.int32))
    assert np.asarray(r.found[0]).all()
    want = [[k % 7, k % 11] for k in staggered] + [[9, 9]]
    want[2] = [42, 43]
    np.testing.assert_array_equal(np.asarray(r.values[0]), want)
    # bit-exact with the bounded host oracle replayed over the same story
    ref = hopscotch.make_table(nb, 2, neighborhood=8)
    for k in staggered:
        assert ref.set_full(k, [k % 7, k % 11]) == hopscotch.SET_INSERTED
    assert ref.set_full(staggered[2], [42, 43]) == hopscotch.SET_UPDATED
    assert ref.set_full(z, [9, 9]) == hopscotch.SET_DISPLACED
    np.testing.assert_array_equal(np.asarray(svc.keys[0]), ref.keys)
    np.testing.assert_array_equal(np.asarray(svc.vals[0]), ref.values)


def test_service_set_many_batched(mesh_kv):
    """The batched service entry point: a driver-dead batch of mixed
    updates/inserts is fully applied and acked."""
    items = [(k, [k, k + 1]) for k in range(1, 9)]
    svc = failure.ShardedKVService.start(items)
    svc.crash_host()
    sk = np.asarray([3, 801, 5, 802], np.int32)
    sv = np.stack([sk * 2, sk * 3], axis=1).astype(np.int32)
    res = svc.set_many(sk, sv)
    assert bool(np.asarray(res.applied).all())
    st = np.asarray(res.status[0])
    np.testing.assert_array_equal(st, [programs.SET_UPDATED,
                                       programs.SET_INSERTED,
                                       programs.SET_UPDATED,
                                       programs.SET_INSERTED])
    r = svc.get_many(sk)
    assert np.asarray(r.found[0]).all()
    np.testing.assert_array_equal(np.asarray(r.values[0]), sv)
