"""Tests for the batched ChainEngine: get_many/serve_many equivalence with
sequential gets, deliver_many, and the Pallas managed-WQ backend vs the
interpreter oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import isa, machine, programs
from repro.core.engine import ChainEngine


# --- deliver_many ------------------------------------------------------------

def test_deliver_many_matches_stacked_deliver():
    srv = programs.build_recycled_get_server(n_buckets=8, val_len=2)
    payloads = np.asarray([[k, srv.bucket_addr(srv.h1(k))]
                           for k in (1, 2, 3)], np.int32)
    batch = machine.deliver_many(srv.state, srv.loop_wq, payloads)
    for i, p in enumerate(payloads):
        ref = machine.deliver(srv.state, srv.loop_wq, list(p))
        for got, want in zip(jax.tree_util.tree_leaves(
                jax.tree_util.tree_map(lambda a: a[i], batch)),
                jax.tree_util.tree_leaves(ref)):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_deliver_many_rejects_oversized_payload():
    srv = programs.build_recycled_get_server(n_buckets=8, val_len=2)
    bad = np.zeros((2, isa.MSG_WORDS + 1), np.int32)
    with pytest.raises(ValueError):
        machine.deliver_many(srv.state, srv.loop_wq, bad)


# --- get_many == N sequential get() -----------------------------------------

@pytest.mark.parametrize("parallel", [True, False])
def test_hash_get_many_matches_sequential(parallel):
    off = programs.build_hash_lookup(n_buckets=16, val_len=2,
                                     parallel=parallel)
    for k in (3, 5, 7, 7 + off.n_buckets):
        off.insert(k, [k * 10, k * 10 + 1])
    keys = [3, 4, 5, 7, 7 + off.n_buckets, 1000, 3]   # hits, misses, repeat
    seq = [off.get(k)[0].tolist() for k in keys]
    vals, out = off.get_many(keys)
    assert vals.tolist() == seq
    # every row ran an independent machine: response counters all advanced
    assert np.asarray(out.responses).shape == (len(keys),)


@pytest.mark.parametrize("use_break", [False, True])
def test_list_get_many_matches_sequential(use_break):
    off = programs.build_list_traversal(n_iters=6, val_len=2,
                                        use_break=use_break)
    off.set_list([(20 + i, [i, i * 3]) for i in range(6)])
    keys = [20, 23, 999, 25, 20]
    seq = [off.get(k)[0].tolist() for k in keys]
    vals, _ = off.get_many(keys)
    assert vals.tolist() == seq


def test_recycled_serve_many_matches_sequential_with_laps():
    """serve_many streams through *persistent* state: values AND on-chain
    lap counters must match N sequential serve() calls exactly."""
    a = programs.build_recycled_get_server(n_buckets=16, val_len=2)
    b = programs.build_recycled_get_server(n_buckets=16, val_len=2)
    for srv in (a, b):
        for k in range(1, 6):
            srv.insert(k, [k * 7, k * 7 + 1])
        srv.load()
    keys = [1, 9, 2, 3, 9, 5, 1]                      # mixed hit/miss
    seq = [a.serve(k).tolist() for k in keys]
    got = b.serve_many(keys).tolist()
    assert got == seq
    laps_a = int(np.asarray(a.state.mem)[a.laps_addr])
    laps_b = int(np.asarray(b.state.mem)[b.laps_addr])
    assert laps_a == laps_b == len(keys)
    np.testing.assert_array_equal(np.asarray(a.state.mem),
                                  np.asarray(b.state.mem))


def test_recycled_serve_many_then_serve_continues():
    """The batch leaves the loop re-armed: a later single serve works."""
    srv = programs.build_recycled_get_server(n_buckets=16, val_len=2)
    srv.insert(3, [33, 34])
    srv.load()
    assert srv.serve_many([5, 3, 6]).tolist() == [[0, 0], [33, 34], [0, 0]]
    assert srv.serve(3).tolist() == [33, 34]


# --- Pallas managed-WQ backend vs interpreter oracle ------------------------

def _recycled_batch(keys):
    srv = programs.build_recycled_get_server(n_buckets=16, val_len=2)
    for k in range(1, 8):
        srv.insert(k, [k * 9, k * 9 + 1])
    srv.load()
    payloads = [srv._payload(int(k)) for k in keys]
    return srv, payloads


def test_pallas_backend_matches_interpreter_recycled_server():
    keys = [1, 12, 3, 7, 15, 2]
    srv, payloads = _recycled_batch(keys)
    eng_i = ChainEngine.for_spec(srv.spec)
    eng_p = ChainEngine.for_spec(srv.spec, "pallas-interpret")
    out_i = eng_i.run_many(srv.state, srv.loop_wq, payloads, 64)
    out_p = eng_p.run_many(srv.state, srv.loop_wq, payloads, 64)
    np.testing.assert_array_equal(np.asarray(out_i.mem),
                                  np.asarray(out_p.mem))
    np.testing.assert_array_equal(np.asarray(out_i.head),
                                  np.asarray(out_p.head))
    np.testing.assert_array_equal(np.asarray(out_i.completions),
                                  np.asarray(out_p.completions))
    np.testing.assert_array_equal(np.asarray(out_i.enable_limit),
                                  np.asarray(out_p.enable_limit))
    np.testing.assert_array_equal(np.asarray(out_i.msg_head),
                                  np.asarray(out_p.msg_head))


def test_pallas_backend_matches_interpreter_straight_line():
    """Single plain WQ (non-managed) chain: atomics incl. return-old,
    plus a client-response SEND (responses counter parity)."""
    from repro.core import assembler
    p = assembler.Program(512)
    x = p.word(5)
    y = p.word(0)
    ret = p.word(0)
    resp = p.word(0)
    wq = p.add_wq(8)
    wq.read(src=x, dst=y)
    wq.add(dst=y, addend=10, ret=ret)
    wq.cas(dst=y, old=15, new=99)
    wq.max_(dst=y, operand=120)
    wq.min_(dst=y, operand=60)
    wq.send(src=y, ln=1, dst_region=resp, target_qp=-1)
    spec, st0 = p.finalize()

    out_i = machine.run(spec, st0, 16)
    eng_p = ChainEngine.for_spec(spec, "pallas-interpret")
    batch = jax.tree_util.tree_map(lambda a: jnp.stack([a] * 3), st0)
    out_p = eng_p.run_batch(batch, 16)
    for r in range(3):
        np.testing.assert_array_equal(np.asarray(out_p.mem[r]),
                                      np.asarray(out_i.mem))
        assert int(out_p.responses[r]) == int(out_i.responses) == 1
        assert int(out_p.steps[r]) == int(out_i.steps)
    assert int(np.asarray(out_i.mem)[ret]) == 5   # ADD returned old value
    assert int(np.asarray(out_i.mem)[resp]) == 60


def test_get_many_empty_batch():
    off = programs.build_hash_lookup(n_buckets=16, val_len=2)
    off.insert(3, [30, 31])
    vals, _ = off.get_many([])
    assert vals.shape == (0, 2)


def test_run_many_gives_fresh_fuel_to_reused_state():
    """A persistent state's cumulative steps counter must not starve a
    later batch (regression: run_many previously inherited it as fuel)."""
    srv = programs.build_recycled_get_server(n_buckets=16, val_len=2)
    for k in range(1, 4):
        srv.insert(k, [k * 9, k * 9 + 1])
    srv.load()
    assert srv.serve(3).tolist() == [27, 28]       # leaves steps > 0
    assert int(np.asarray(srv.state.steps)) > 0
    payloads = [srv._payload(k) for k in (1, 2, 3)]
    want = [[9, 10], [18, 19], [27, 28]]
    for backend in ("interp", "pallas-interpret"):
        out = ChainEngine.for_spec(srv.spec, backend).run_many(
            srv.state, srv.loop_wq, payloads, 16)
        got = np.asarray(out.mem[:, srv.resp_region:
                                 srv.resp_region + 2]).tolist()
        assert got == want, backend
        # steps counts executed WRs identically on both backends
        np.testing.assert_array_equal(np.asarray(out.steps),
                                      [12, 12, 12])


def test_run_batch_fuel_parity_across_backends():
    """run_batch must treat a state's cumulative steps as consumed fuel on
    both backends (regression: pallas granted fresh fori_loop fuel)."""
    srv = programs.build_recycled_get_server(n_buckets=16, val_len=2)
    for k in range(1, 4):
        srv.insert(k, [k * 9, k * 9 + 1])
    srv.load()
    srv.serve(3)                                   # state.steps becomes 12
    payloads = np.asarray([srv._payload(k) for k in (1, 2, 3)], np.int32)
    outs = {}
    for backend in ("interp", "pallas-interpret"):
        eng = ChainEngine.for_spec(srv.spec, backend)
        batch = eng.deliver_many(srv.state, srv.loop_wq, payloads)
        outs[backend] = eng.run_batch(batch, 16)   # only 4 WRs of fuel left
    np.testing.assert_array_equal(np.asarray(outs["interp"].mem),
                                  np.asarray(outs["pallas-interpret"].mem))
    np.testing.assert_array_equal(np.asarray(outs["interp"].steps),
                                  np.asarray(outs["pallas-interpret"].steps))
    assert np.asarray(outs["interp"].steps).tolist() == [16, 16, 16]


def test_run_many_zero_word_payloads_are_delivered():
    """(N, 0) payloads are N empty-message triggers, not an empty batch."""
    off = programs.build_hash_lookup(n_buckets=16, val_len=2)
    out = off.engine.run_many(off.materialize(), off.recv_wq,
                              np.zeros((3, 0), np.int32), 64)
    assert out.mem.shape[0] == 3
    assert np.asarray(out.msg_head[:, off.recv_wq]).tolist() == [1, 1, 1]


def test_pallas_backend_respects_pre_halted_state():
    """A HALTed machine must stay stopped on both backends (regression:
    pallas re-executed WRs and cleared the halted flag)."""
    from repro.core import assembler
    p = assembler.Program(256)
    v = p.word(1)
    wq = p.add_wq(4)
    wq.halt()
    wq.write_imm(dst=v, value=99)
    spec, st0 = p.finalize()
    halted = machine.run(spec, st0, 8)             # executes only HALT
    assert bool(halted.halted) and int(halted.mem[v]) == 1
    batch = jax.tree_util.tree_map(lambda a: jnp.stack([a] * 2), halted)
    for backend in ("interp", "pallas-interpret"):
        out = ChainEngine.for_spec(spec, backend).run_batch(batch, 8)
        assert np.asarray(out.mem[:, v]).tolist() == [1, 1], backend
        assert np.asarray(out.halted).tolist() == [True, True], backend


def test_recycled_get_many_returns_vals_and_state():
    srv = programs.build_recycled_get_server(n_buckets=8, val_len=2)
    srv.insert(2, [5, 6])
    srv.load()
    vals, state = srv.get_many([2, 7, 2])
    assert vals.tolist() == [[5, 6], [0, 0], [5, 6]]
    assert state is srv.state


def test_pallas_backend_rejects_inter_qp_send():
    from repro.core import assembler
    p = assembler.Program(256)
    v = p.word(42)
    wq = p.add_wq(2)
    wq.send(src=v, ln=1, target_qp=0)              # SEND to self
    spec, st0 = p.finalize()
    eng = ChainEngine.for_spec(spec, "pallas-interpret")
    batch = jax.tree_util.tree_map(lambda a: jnp.stack([a]), st0)
    with pytest.raises(ValueError, match="inter-QP SEND"):
        eng.run_batch(batch, 8)


def test_pallas_backend_rejects_multi_wq_specs():
    off = programs.build_hash_lookup(n_buckets=16, val_len=2)
    with pytest.raises(ValueError):
        ChainEngine(off.spec, backend="pallas-interpret")


def test_engine_for_spec_is_cached():
    srv = programs.build_recycled_get_server(n_buckets=8, val_len=2)
    assert ChainEngine.for_spec(srv.spec) is ChainEngine.for_spec(srv.spec)


def test_pallas_send_validation_keyed_on_image(monkeypatch):
    """Engines are memoized per (spec, backend), so the inter-QP-SEND
    subset check must be keyed on the code-region *image*: after one valid
    image is validated, a different image with the same spec must still be
    scanned (regression: a one-shot boolean skipped it on the compiled TPU
    fast path, silently no-op'ing the SEND)."""
    from repro.core import assembler

    def build(bad):
        p = assembler.Program(320)
        v = p.word(42)
        d = p.word(0)
        wq = p.add_wq(2)
        if bad:
            wq.send(src=v, ln=1, target_qp=0)      # inter-QP SEND to self
        else:
            wq.write(src=v, dst=d)
        return p.finalize()

    spec_good, st_good = build(False)
    spec_bad, st_bad = build(True)
    assert spec_good == spec_bad                   # same spec, two images
    eng = ChainEngine(spec_good, backend="pallas-interpret")
    # simulate the compiled-TPU fast path the old one-shot flag guarded
    # (backend="pallas-interpret" keeps the kernel in interpret mode)
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")

    batch = jax.tree_util.tree_map(lambda a: jnp.stack([a]), st_good)
    out = eng.run_batch(batch, 8)                  # validates the good image
    assert int(np.asarray(out.mem)[0, spec_good.mem_words - 2]) == 42

    bad_batch = jax.tree_util.tree_map(lambda a: jnp.stack([a]), st_bad)
    with pytest.raises(ValueError, match="inter-QP SEND"):
        eng.run_batch(bad_batch, 8)

    # the validated image still runs (the cache keeps keying correctly)
    eng.run_batch(batch, 8)


def test_run_many_accepts_traced_device_payloads():
    """run_many must work on jnp payloads without a host round-trip (the
    sharded serving path delivers traced arrays inside shard_map)."""
    off = programs.build_hash_lookup(n_buckets=16, val_len=2)
    off.insert(3, [30, 31])
    off.insert(5, [50, 51])
    st = off.materialize()
    pays_np = np.asarray([off._payload(k) for k in (3, 5, 9)], np.int32)
    want, _ = off.get_many([3, 5, 9])

    got_state = jax.jit(
        lambda s, p: off.engine.run_many(s, off.recv_wq, p, 256))(
            st, jnp.asarray(pays_np))
    got = np.asarray(got_state.mem[:, off.resp_region:
                                   off.resp_region + off.val_len])
    np.testing.assert_array_equal(got, want)
