"""§5.5 contention/isolation scenario (benchmarks/contention.py)."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import contention  # noqa: E402


def test_latency_model_isolation_ratio():
    """Victim latency drops by >= 10x with the token bucket (paper: ~35x);
    deferral is what buys it — the flooder is capped at its burst."""
    model = contention.latency_model(flood=512, svc_us=3.5)
    assert model["isolation_latency_ratio"] >= 10.0
    assert model["deferred_flood_requests"] == 512 - int(contention.BURST)
    assert (model["victim_mean_us_isolation_on"]
            < model["victim_p99_us_isolation_off"])


def test_real_serving_under_contention():
    """The actual sharded chain path: victims starved without admission
    (drops reported, not read as misses), fully served and oracle-exact
    with it."""
    real = contention.real_isolated_serving(flood=24, capacity=24)
    assert real["no_victim_served_off"]
    assert real["all_victims_served_on"]
    assert real["victims_bit_exact_with_oracle"]
    assert real["deferred_isolation_on"] == 24 - int(contention.BURST)


@pytest.mark.slow
def test_contention_benchmark_long_run(tmp_path):
    """The full batch-4096 run records the isolation-on/off latency ratio
    and merges it into the BENCH json."""
    out = tmp_path / "BENCH_chains.json"
    results = contention.main(out_path=str(out), long=True)
    assert out.exists()
    model = results["contention"]["model"]
    assert model["batch"] == 4096
    assert results["checks"]["contention_isolation_ratio_10x"]
    assert results["checks"]["contention_victims_bit_exact"]
    assert results["checks"]["contention_flood_starves_without_isolation"]
