"""Online resize while serving (§5.6 extension): the migrator chain vs
the ``HopscotchTable.grow`` oracle, the double-frame get/set paths, the
watermark routing invariants, and the completed §5.6 growth story (an
insert that forces table growth lands, and the resize runs to cutover,
with the host driver dead).  Includes the escalation-boundary
satellites: duplicate keys in one batch where one forces growth, and
mid-migration gets for keys whose buckets sit exactly at the watermark.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import programs
from repro.kvstore import hopscotch, store
from repro.rdma import failure

NB, H, V = 32, 4, 2


@pytest.fixture(scope="module")
def mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("kv",))


@pytest.fixture(scope="module")
def migrator():
    return programs.build_hopscotch_migrator(NB, V, H)


def _keys_with_home(bucket, count, n_buckets=NB, start=1):
    return store.keys_homed_at(bucket, count, n_buckets, start=start,
                               n_shards=1)


def _filled_table(n_keys, seed=0, nb=NB, h=H):
    t = hopscotch.make_table(nb, V, neighborhood=h)
    rng = np.random.RandomState(seed)
    ks, k = [], 1
    while len(ks) < n_keys:
        if t.insert(k, [k % 7 + 1, k % 11 + 1]):
            ks.append(k)
        k += 1 + int(rng.randint(4))
    return t, ks


def _mig_parity(mig, t, new, b):
    """One migrator lap vs one ``migrate_bucket`` oracle step; asserts
    bit-exactness of status and all four arrays."""
    ok, ov = t.as_device()
    nk, nv = new.as_device()
    ref_old = hopscotch.HopscotchTable(t.keys.copy(), t.values.copy(), H)
    ref_new = hopscotch.HopscotchTable(new.keys.copy(), new.values.copy(),
                                       H)
    pay = mig.device_payloads(jnp.asarray([b], jnp.int32), ok)
    st, ok, ov, nk, nv = mig.run_one(ok, ov, nk, nv, pay[0], mig.fuel)
    ref_st = ref_old.migrate_bucket(ref_new, b)
    assert int(st) == ref_st
    np.testing.assert_array_equal(np.asarray(ok), ref_old.keys)
    np.testing.assert_array_equal(np.asarray(ov), ref_old.values)
    np.testing.assert_array_equal(np.asarray(nk), ref_new.keys)
    np.testing.assert_array_equal(np.asarray(nv), ref_new.values)
    return int(st), nk, nv


# --- the migrator program vs the per-bucket oracle ---------------------------

def test_mig_status_codes_match_across_layers():
    assert hopscotch.MIG_MOVED == programs.MIG_MOVED
    assert hopscotch.MIG_DISCARDED == programs.MIG_DISCARDED
    assert hopscotch.MIG_NEEDS_DISPLACE == programs.MIG_NEEDS_DISPLACE


def test_migrator_full_sweep_bit_exact(migrator):
    """Every source bucket of a populated table through the chain, each
    lap bit-exact with ``migrate_bucket``; afterwards the old frame is
    empty and every key serves from the new frame."""
    t, ks = _filled_table(12, seed=0)
    new = hopscotch.make_table(2 * NB, V, neighborhood=H)
    ok, ov = t.as_device()
    nk, nv = new.as_device()
    ref_old = hopscotch.HopscotchTable(t.keys.copy(), t.values.copy(), H)
    ref_new = hopscotch.HopscotchTable(new.keys.copy(), new.values.copy(),
                                       H)
    for b in range(NB):
        pay = migrator.device_payloads(jnp.asarray([b], jnp.int32), ok)
        ref_st = ref_old.migrate_bucket(ref_new, b)
        if int(pay[0][0]) == 0:
            assert ref_st == 0          # EMPTY source: never dispatched
            continue
        st, ok, ov, nk, nv = migrator.run_one(ok, ov, nk, nv, pay[0],
                                              migrator.fuel)
        assert int(st) == ref_st == hopscotch.MIG_MOVED
        np.testing.assert_array_equal(np.asarray(nk), ref_new.keys)
        np.testing.assert_array_equal(np.asarray(nv), ref_new.values)
    assert (np.asarray(ok) == hopscotch.EMPTY).all()
    f, v = hopscotch.lookup(nk, nv, jnp.asarray(ks, jnp.int32), H)
    assert bool(jnp.all(f))
    for i, k in enumerate(ks):
        assert v[i].tolist() == [k % 7 + 1, k % 11 + 1]


def test_migrator_discard_keeps_newer_value(migrator):
    """The double-residency transient: the key was re-written into the
    new frame while the stale copy awaited migration — the migrator must
    drop the old copy, never clobber the newer value."""
    t = hopscotch.make_table(NB, V, neighborhood=H)
    k = 5
    assert t.insert(k, [1, 1])
    b = int(np.where(t.keys == k)[0][0])
    new = hopscotch.make_table(2 * NB, V, neighborhood=H)
    assert new.insert(k, [9, 9])        # the fresher copy
    st, nk, nv = _mig_parity(migrator, t, new, b)
    assert st == hopscotch.MIG_DISCARDED
    f, v = hopscotch.lookup(nk, nv, jnp.asarray([k], jnp.int32), H)
    assert bool(f[0]) and v[0].tolist() == [9, 9]


def test_migrator_needs_displace_leaves_frames_untouched(migrator):
    t = hopscotch.make_table(NB, V, neighborhood=H)
    kk = _keys_with_home(3, 1)[0]
    assert t.insert(kk, [2, 3])
    b = int(np.where(t.keys == kk)[0][0])
    hn = int(hopscotch.bucket_of(kk, 2 * NB))
    new = hopscotch.make_table(2 * NB, V, neighborhood=H)
    start = 1
    for d in range(H):
        want = (hn + d) % (2 * NB)
        c = _keys_with_home(want, 1, 2 * NB, start=start)[0]
        if c == kk:
            c = _keys_with_home(want, 1, 2 * NB, start=c + 1)[0]
        start = c + 1
        new.keys[want] = c
        new.values[want] = [c % 5 + 1, c % 3 + 1]
    kb, nb_ = t.keys.copy(), new.keys.copy()
    st, nk, nv = _mig_parity(migrator, t, new, b)
    assert st == hopscotch.MIG_NEEDS_DISPLACE
    np.testing.assert_array_equal(t.keys, kb)       # oracle untouched too
    np.testing.assert_array_equal(np.asarray(nk), nb_)


def test_migrator_select_covers_both_halves(migrator):
    """The Calc-verb select branch: keys whose next hash bit is 0 land in
    the lower half-neighborhood, bit-1 keys in the upper — both arms
    exercised and bit-exact."""
    shift = NB.bit_length() - 1
    done = {0: False, 1: False}
    k = 1
    while not all(done.values()):
        ku = (k * 2654435761) & 0xFFFFFFFF
        sel = (ku >> shift) & 1
        t = hopscotch.make_table(NB, V, neighborhood=H)
        assert t.insert(k, [4, 4])
        b = int(np.where(t.keys == k)[0][0])
        new = hopscotch.make_table(2 * NB, V, neighborhood=H)
        st, nk, nv = _mig_parity(migrator, t, new, b)
        assert st == hopscotch.MIG_MOVED
        row = int(np.where(np.asarray(nk) == k)[0][0])
        hn = int(hopscotch.bucket_of(k, 2 * NB))
        assert (row - hn) % (2 * NB) < H
        assert hn == int(hopscotch.bucket_of(k, NB)) + sel * NB
        done[sel] = True
        k += 1


def test_migrator_zero_padded_request_is_inert(migrator):
    t, _ = _filled_table(8, seed=3)
    new = hopscotch.make_table(2 * NB, V, neighborhood=H)
    ok, ov = t.as_device()
    nk, nv = new.as_device()
    st, ok2, ov2, nk2, nv2 = migrator.run_one(
        ok, ov, nk, nv, jnp.zeros(4, jnp.int32), migrator.fuel)
    assert int(st) == 0
    np.testing.assert_array_equal(np.asarray(ok2), t.keys)
    np.testing.assert_array_equal(np.asarray(ov2), t.values)
    np.testing.assert_array_equal(np.asarray(nk2), new.keys)
    np.testing.assert_array_equal(np.asarray(nv2), new.values)


def test_migrator_build_bounds():
    with pytest.raises(ValueError, match="power-of-two"):
        programs.build_hopscotch_migrator(33, V, H)
    with pytest.raises(ValueError, match="row copy"):
        programs.build_hopscotch_migrator(NB, 17, H)
    with pytest.raises(ValueError, match="power-of-two"):
        hopscotch.make_table(33, V, neighborhood=H).grow()
    with pytest.raises(ValueError, match="power-of-two"):
        store.begin_resize(jnp.zeros((1, 33), jnp.int32),
                           jnp.zeros((1, 33, V), jnp.int32))


# --- the sharded resize driver ------------------------------------------------

def test_sharded_resize_matches_grow_oracle(mesh1):
    """Quantum-driven migration to cutover: the final doubled frame is
    bit-identical to ``grow(step=quantum)``, the old frame is drained,
    and every mid-flight quantum's frames match the replayed oracle."""
    t, ks = _filled_table(14, seed=1)
    ref = hopscotch.HopscotchTable(t.keys.copy(), t.values.copy(), H)
    dk, dv = t.as_device()
    rs = store.begin_resize(dk[None], dv[None])
    grown = ref.grow(step=8)
    while not store.resize_done(rs):
        rs, rep = store.sharded_resize(mesh1, "kv", rs, step=8,
                                       neighborhood=H)
        assert int(np.asarray(rep.stuck).sum()) == 0
    nk, nv = store.finish_resize(rs)
    assert nk.shape == (1, 2 * NB)
    np.testing.assert_array_equal(np.asarray(nk[0]), grown.keys)
    np.testing.assert_array_equal(np.asarray(nv[0]), grown.values)
    np.testing.assert_array_equal(np.asarray(rs.keys[0]), ref.keys)
    assert (ref.keys == hopscotch.EMPTY).all()


def test_sharded_resize_escalates_through_displacer(mesh1):
    """A source key whose doubled-frame neighborhood is already full must
    escalate through the new frame's displacer chain — placed, source
    vacated, reported, and bit-exact with the quantum-scheduled oracle."""
    t = hopscotch.make_table(NB, V, neighborhood=H)
    kk = _keys_with_home(2, 1)[0]
    assert t.insert(kk, [3, 4])
    hn = int(hopscotch.bucket_of(kk, 2 * NB))
    new = hopscotch.make_table(2 * NB, V, neighborhood=H)
    start = 1
    for d in range(H):
        want = (hn + d) % (2 * NB)
        c = _keys_with_home(want, 1, 2 * NB, start=start)[0]
        if c == kk:
            c = _keys_with_home(want, 1, 2 * NB, start=c + 1)[0]
        start = c + 1
        assert new.insert(c, [c % 5 + 1, c % 3 + 1])
    ref_old = hopscotch.HopscotchTable(t.keys.copy(), t.values.copy(), H)
    ref_new = hopscotch.HopscotchTable(new.keys.copy(), new.values.copy(),
                                       H)
    rs = store.ResizeState(
        keys=jnp.asarray(t.keys)[None], vals=jnp.asarray(t.values)[None],
        new_keys=jnp.asarray(new.keys)[None],
        new_vals=jnp.asarray(new.values)[None],
        watermark=jnp.zeros((1,), jnp.int32))
    rs, rep = store.sharded_resize(mesh1, "kv", rs, step=8, neighborhood=H)
    assert int(np.asarray(rep.escalated)[0]) == 1
    assert int(np.asarray(rep.stuck)[0]) == 0
    # oracle replay of the same quantum schedule
    pending = []
    for b in range(8):
        if ref_old.migrate_bucket(ref_new, b) == hopscotch.MIG_NEEDS_DISPLACE:
            pending.append(b)
    assert pending
    for b in pending:
        k = int(ref_old.keys[b])
        st2 = ref_new.set_full(k, ref_old.values[b].tolist())
        assert st2 == hopscotch.SET_DISPLACED
        ref_old.keys[b] = hopscotch.EMPTY
        ref_old.values[b] = 0
    np.testing.assert_array_equal(np.asarray(rs.keys[0]), ref_old.keys)
    np.testing.assert_array_equal(np.asarray(rs.new_keys[0]), ref_new.keys)
    np.testing.assert_array_equal(np.asarray(rs.new_vals[0]),
                                  ref_new.values)


def test_finish_resize_guards():
    rs = store.begin_resize(jnp.zeros((1, NB), jnp.int32),
                            jnp.zeros((1, NB, V), jnp.int32))
    with pytest.raises(ValueError, match="incomplete"):
        store.finish_resize(rs)
    # a resident left in the old frame after a "full" sweep must raise
    bad = rs._replace(watermark=jnp.full((1,), NB, jnp.int32),
                      keys=rs.keys.at[0, 3].set(7))
    with pytest.raises(RuntimeError, match="resident"):
        store.finish_resize(bad)


# --- double-frame serving -----------------------------------------------------

def _mid_migration_state(mesh1, n_keys=12, seed=2, step=8):
    t, ks = _filled_table(n_keys, seed=seed)
    ref = hopscotch.HopscotchTable(t.keys.copy(), t.values.copy(), H)
    dk, dv = t.as_device()
    rs = store.begin_resize(dk[None], dv[None])
    rs, _ = store.sharded_resize(mesh1, "kv", rs, step=step,
                                 neighborhood=H)
    return rs, ks, ref


def _oracle_double_get(rs, q):
    fn, vn = hopscotch.lookup(rs.new_keys[0], rs.new_vals[0],
                              jnp.asarray(q, jnp.int32), H)
    fo, vo = hopscotch.lookup(rs.keys[0], rs.vals[0],
                              jnp.asarray(q, jnp.int32), H)
    f = np.asarray(fn) | np.asarray(fo)
    v = np.where(np.asarray(fn)[:, None], np.asarray(vn), np.asarray(vo))
    return f, v


def test_get_migrating_bit_exact_all_watermarks(mesh1):
    """Hits, misses, and the query-0 ghost guard stay bit-exact with the
    two-frame oracle at every watermark of a full migration."""
    t, ks = _filled_table(12, seed=2)
    dk, dv = t.as_device()
    rs = store.begin_resize(dk[None], dv[None])
    q = np.asarray(ks + [999983, 0], np.int32)
    while not store.resize_done(rs):
        rs, _ = store.sharded_resize(mesh1, "kv", rs, step=8,
                                     neighborhood=H)
        g = store.sharded_get_migrating(mesh1, "kv", rs,
                                        jnp.asarray(q[None]),
                                        neighborhood=H)
        f_ref, v_ref = _oracle_double_get(rs, q)
        np.testing.assert_array_equal(np.asarray(g.found[0]), f_ref)
        np.testing.assert_array_equal(np.asarray(g.values[0]), v_ref)
        assert bool(np.asarray(g.ok[0]).all())
        assert not bool(np.asarray(g.found[0])[-1])   # query 0: still a miss


def test_get_migrating_bucket_exactly_at_watermark(mesh1):
    """The boundary satellite: one key resident exactly *at* the
    watermark bucket (not yet migrated — must come from the old frame)
    and one just behind it (migrated — must come from the new frame)."""
    t = hopscotch.make_table(NB, V, neighborhood=H)
    at_w = _keys_with_home(8, 1)[0]       # will sit at bucket 8 == w
    behind = _keys_with_home(7, 1)[0]     # at bucket 7 == w - 1
    assert t.insert(at_w, [11, 12]) and t.insert(behind, [13, 14])
    dk, dv = t.as_device()
    rs = store.begin_resize(dk[None], dv[None])
    rs, _ = store.sharded_resize(mesh1, "kv", rs, step=8, neighborhood=H)
    assert int(np.asarray(rs.watermark)[0]) == 8
    # the frame split really is at the watermark
    assert int(np.asarray(rs.keys[0])[8]) == at_w          # old frame
    assert int(np.asarray(rs.keys[0])[7]) == hopscotch.EMPTY
    assert behind in np.asarray(rs.new_keys[0]).tolist()   # new frame
    q = np.asarray([at_w, behind], np.int32)
    g = store.sharded_get_migrating(mesh1, "kv", rs, jnp.asarray(q[None]),
                                    neighborhood=H)
    assert bool(np.asarray(g.found[0]).all())
    np.testing.assert_array_equal(np.asarray(g.values[0]),
                                  [[11, 12], [13, 14]])


def test_set_migrating_routes_and_survives_cutover(mesh1):
    """Watermark routing: a write for a key whose home is behind the
    watermark — but whose displaced *residence* is still ahead of it —
    goes to the new frame, leaving the stale old copy as the intended
    transient the migrator later discards; an unmigrated-home update
    goes to the old frame in place; a fresh ahead-of-watermark insert
    claims an old bucket; all values survive to cutover."""
    t = hopscotch.make_table(NB, V, neighborhood=H)
    k6a = _keys_with_home(6, 1)[0]
    k7 = _keys_with_home(7, 1)[0]
    k6b = _keys_with_home(6, 2, start=k6a + 1)[1]   # displaced to bucket 8
    k20 = _keys_with_home(20, 1)[0]
    for k in (k6a, k7, k6b, k20):
        assert t.insert(k, [k % 9 + 1, k % 5 + 1])
    assert int(t.keys[8]) == k6b                    # straddles the cut
    dk, dv = t.as_device()
    rs = store.begin_resize(dk[None], dv[None])
    rs, _ = store.sharded_resize(mesh1, "kv", rs, step=8, neighborhood=H)
    assert int(np.asarray(rs.watermark)[0]) == 8

    fresh = 77001                                   # home 25: routes old
    assert 8 <= int(hopscotch.bucket_of(fresh, NB)) <= NB - H
    sk = np.asarray([k6b, k20, fresh], np.int32)
    sv = np.stack([sk % 61 + 1, sk % 53 + 1], axis=1).astype(np.int32)
    res, rs = store.sharded_set_migrating(
        mesh1, "kv", rs, jnp.asarray(sk[None]), jnp.asarray(sv[None]),
        neighborhood=H)
    assert bool(np.asarray(res.ok[0]).all())
    assert bool(np.asarray(res.applied[0]).all())
    st = np.asarray(res.status[0])
    assert st[0] == programs.SET_INSERTED         # new frame, fresh claim
    assert st[1] == programs.SET_UPDATED          # old frame, in place
    assert st[2] == programs.SET_INSERTED         # old frame, ahead of w
    # k6b now lives in BOTH frames: new copy fresh, old copy stale
    assert k6b in np.asarray(rs.new_keys[0]).tolist()
    assert int(np.asarray(rs.keys[0])[8]) == k6b
    # double-frame gets see the fresh values immediately (new frame wins)
    g = store.sharded_get_migrating(mesh1, "kv", rs, jnp.asarray(sk[None]),
                                    neighborhood=H)
    assert bool(np.asarray(g.found[0]).all())
    np.testing.assert_array_equal(np.asarray(g.values[0]), sv)
    # ... and after the migrator discards the stale copy, they survive
    discarded = 0
    while not store.resize_done(rs):
        rs, rep = store.sharded_resize(mesh1, "kv", rs, step=8,
                                       neighborhood=H)
        discarded += int(np.asarray(rep.discarded).sum())
    assert discarded == 1                          # exactly the stale k6b
    nk, nv = store.finish_resize(rs)
    g2 = store.sharded_get(mesh1, "kv", nk, nv, jnp.asarray(sk[None]),
                           neighborhood=H)
    assert bool(np.asarray(g2.found[0]).all())
    np.testing.assert_array_equal(np.asarray(g2.values[0]), sv)


def test_set_migrating_wrap_home_routes_new(mesh1):
    """A key whose old neighborhood wraps past the frame end must write
    the new frame even at watermark ~0 — an old-frame claim could land
    behind the watermark and be lost at cutover."""
    t = hopscotch.make_table(NB, V, neighborhood=H)
    dk, dv = t.as_device()
    rs = store.begin_resize(dk[None], dv[None])
    wrap = _keys_with_home(NB - 1, 1)[0]       # home + H wraps
    sk = np.asarray([wrap], np.int32)
    sv = np.asarray([[5, 6]], np.int32)
    res, rs = store.sharded_set_migrating(
        mesh1, "kv", rs, jnp.asarray(sk[None]), jnp.asarray(sv[None]),
        neighborhood=H)
    assert int(np.asarray(res.status[0])[0]) == programs.SET_INSERTED
    assert wrap in np.asarray(rs.new_keys[0]).tolist()
    assert wrap not in np.asarray(rs.keys[0]).tolist()
    while not store.resize_done(rs):
        rs, _ = store.sharded_resize(mesh1, "kv", rs, step=8,
                                     neighborhood=H)
    nk, nv = store.finish_resize(rs)
    g = store.sharded_get(mesh1, "kv", nk, nv, jnp.asarray(sk[None]),
                          neighborhood=H)
    assert bool(g.found[0][0])
    np.testing.assert_array_equal(np.asarray(g.values[0][0]), [5, 6])


def test_set_migrating_never_reports_internal_status(mesh1):
    """Capacity pressure across the two write stages must never surface
    SET_NEEDS_DISPLACEMENT (internal-only): a row the second stage had
    to drop comes back ok=False with status 0."""
    rs, ks, _ = _mid_migration_state(mesh1, n_keys=10, seed=5)
    w = int(np.asarray(rs.watermark)[0])
    migrated = [k for k in ks if int(hopscotch.bucket_of(k, NB)) < w]
    assert len(migrated) >= 2
    sk = np.asarray(migrated[:2], np.int32)    # both route to the new frame
    sv = np.stack([sk % 61 + 1, sk % 53 + 1], axis=1).astype(np.int32)
    res, rs = store.sharded_set_migrating(
        mesh1, "kv", rs, jnp.asarray(sk[None]), jnp.asarray(sv[None]),
        neighborhood=H, capacity=1)
    st = np.asarray(res.status[0])
    ok = np.asarray(res.ok[0])
    assert programs.SET_NEEDS_DISPLACEMENT not in st.tolist()
    assert ok.sum() == 1 and int(res.dropped[0]) == 1
    assert st[~ok].tolist() == [0]


# --- the §5.6 growth story (service auto-escalation, driver dead) ------------

def _stuck_neighborhood_items(nb=NB, h=8):
    """Items that fill one neighborhood with same-home keys and pad the
    following buckets with immovable residents: the next same-home
    insert dead-ends the bounded bubble -> SET_NEEDS_RESIZE."""
    cl = store.keys_homed_at(7, 9, nb, start=1, n_shards=1)
    items = [(k, [k % 9 + 1, k % 5 + 1]) for k in cl[:8]]
    for d in range(h, h + 16):
        kk = store.keys_homed_at((7 + d) % nb, 1, nb,
                                 start=3000 + 7 * d, n_shards=1)[0]
        items.append((kk, [kk % 9 + 1, kk % 5 + 1]))
    return items, cl[8]


def test_service_insert_forcing_growth_serves_with_driver_dead():
    """The §5.6 acceptance scenario: driver killed first, then an insert
    that forces table growth — the service auto-escalates into an
    incremental resize, the insert lands, gets/sets keep serving
    mid-resize, and the migration completes to cutover, all without a
    host driver."""
    items, z = _stuck_neighborhood_items()
    svc = failure.ShardedKVService.start(items, buckets_per_shard=NB)
    svc.resize_quantum = 8
    svc.crash_host()
    assert not svc.host_alive()

    assert svc.set(z, [42, 43])                # forced growth, still lands
    assert svc.resizing()

    expect = {k: v for k, v in items}
    expect[z] = [42, 43]
    keys = list(expect)
    g = svc.get_many(np.asarray(keys, np.int32))   # serves mid-resize
    assert bool(np.asarray(g.found[0]).all())
    for i, k in enumerate(keys):
        assert np.asarray(g.values[0][i]).tolist() == expect[k]
    assert svc.resizing()                      # still migrating

    assert svc.set(123457, [7, 7])             # sets mid-resize too
    expect[123457] = [7, 7]
    keys.append(123457)

    svc.drive_resize()                         # chain work only: no host
    assert not svc.resizing() and svc.resizes_completed == 1
    assert svc.keys.shape == (1, 2 * NB)       # doubled and cut over
    g = svc.get_many(np.asarray(keys, np.int32))
    assert bool(np.asarray(g.found[0]).all())
    for i, k in enumerate(keys):
        assert np.asarray(g.values[0][i]).tolist() == expect[k]
    assert not svc.host_alive()                # dead the whole time


def test_service_duplicate_keys_one_forces_growth():
    """The escalation-boundary satellite: duplicates of one key in the
    same batch where the first forces growth — the first must land as an
    insert through the auto-resize, the second must observe it and
    resolve to an update (batch order preserved across the re-issue)."""
    items, z = _stuck_neighborhood_items()
    svc = failure.ShardedKVService.start(items, buckets_per_shard=NB)
    svc.resize_quantum = 8
    svc.crash_host()
    sk = np.asarray([z, z], np.int32)
    sv = np.asarray([[1, 1], [2, 2]], np.int32)
    res = svc.set_many(sk, sv)
    st = np.asarray(res.status[0])
    assert svc.resizing()
    assert st[0] in (programs.SET_INSERTED, programs.SET_DISPLACED)
    assert st[1] == programs.SET_UPDATED
    g = svc.get_many(np.asarray([z], np.int32))
    assert bool(g.found[0][0])
    np.testing.assert_array_equal(np.asarray(g.values[0][0]), [2, 2])
    svc.drive_resize()
    g = svc.get_many(np.asarray([z], np.int32))
    np.testing.assert_array_equal(np.asarray(g.values[0][0]), [2, 2])


def test_service_auto_resize_can_be_disabled():
    items, z = _stuck_neighborhood_items()
    svc = failure.ShardedKVService.start(items, buckets_per_shard=NB)
    svc.auto_resize = False
    assert not svc.set(z, [1, 2])              # plain needs-resize report
    assert not svc.resizing()
