"""Tests for the static chain-program verifier (core/analysis.py).

Engineered-bad programs prove each pass actually fires; the registry
sweep proves every shipped builder is clean-or-waivered; the certificate
tests tie the static bounds back to `budget()` and `ChainEngine` fuel.

Bad programs are built by mutating the posted WR dicts *after* `post()`
(the builder itself now rejects these statically — that rejection is
tested too), which mirrors how a buggy generator or a hand-patched image
would reach the verifier.
"""
import pytest

from repro.core import analysis, assembler, isa


def report(prog, waivers=(), name="t"):
    return analysis.verify_program(prog, waivers=waivers, name=name)


def errors_of(rep, pass_name):
    return [f for f in rep.errors if f.pass_name == pass_name]


# ---------------------------------------------------------------------------
# pass: bounds & encoding
# ---------------------------------------------------------------------------

def test_bounds_flags_out_of_bounds_copy():
    p = assembler.Program(256)
    a = p.alloc(4)
    wq = p.add_wq(2)
    wq.write(src=a, dst=a, ln=4)
    wq.wrs[0]["ln"] = isa.MAX_COPY + 1          # post() would reject this
    errs = errors_of(report(p), analysis.PASS_BOUNDS)
    assert len(errs) == 1 and "MAX_COPY" in errs[0].message


def test_bounds_flags_range_outside_memory():
    p = assembler.Program(256)
    wq = p.add_wq(2)
    wq.write(src=250, dst=0, ln=8)              # [250, 258) > mem_words
    errs = errors_of(report(p), analysis.PASS_BOUNDS)
    assert errs and "src range" in errs[0].message


def test_bounds_flags_bad_opcode_and_scatter():
    p = assembler.Program(256)
    tbl = p.scatter_table([10, 11])
    wq = p.add_wq(3)
    wq.recv(scatter_table=tbl)
    wq.noop()
    wq.wrs[1]["ctrl"] = isa.pack_ctrl(isa.NUM_OPCODES + 3, 0)
    wq.wrs[1]["opcode"] = isa.NUM_OPCODES + 3
    p._data_init[tbl] = isa.MAX_SCATTER + 1     # corrupt the table length
    msgs = [f.message for f in errors_of(report(p), analysis.PASS_BOUNDS)]
    assert any("invalid opcode" in m for m in msgs)
    assert any("scatter table length" in m for m in msgs)


# ---------------------------------------------------------------------------
# pass: self-modification audit
# ---------------------------------------------------------------------------

def _selfmod_prog(target_ordering):
    """WQ1 patches WQ0's second slot; WQ0 runs under `target_ordering`
    with no WAIT/ENABLE ordering the patch before the fetch."""
    p = assembler.Program(512)
    v = p.word(7)
    wq0 = p.add_wq(4, ordering=target_ordering)
    wq1 = p.add_wq(4, ordering=isa.ORD_DOORBELL)
    wq0.noop()
    t = wq0.write(src=v, dst=v)
    wq1.write_imm(dst=t.addr("src"), value=v)
    return p


def test_selfmod_stale_prefetch_is_error_under_ord_wq():
    errs = errors_of(report(_selfmod_prog(isa.ORD_WQ)),
                     analysis.PASS_SELFMOD)
    assert len(errs) == 1 and "stale-prefetch" in errs[0].message


def test_selfmod_unordered_patch_is_error_even_one_by_one():
    # doorbell fetch is one-by-one but nothing orders the patch before
    # the target's predecessor retires -> still an error (different one)
    errs = errors_of(report(_selfmod_prog(isa.ORD_DOORBELL)),
                     analysis.PASS_SELFMOD)
    assert len(errs) == 1 and "unordered patch" in errs[0].message


def test_selfmod_wait_ordered_patch_is_clean():
    p = assembler.Program(512)
    v = p.word(7)
    wq0 = p.add_wq(4, ordering=isa.ORD_DOORBELL)
    wq1 = p.add_wq(4, ordering=isa.ORD_DOORBELL)
    wq1.write_imm(dst=wq0.future_wr_addr(1, "src"), value=v)
    wq0.wait(wq1, 1)                    # patch lands before slot 1 fetch
    wq0.write(src=-1, dst=v)
    rep = report(p)
    assert not errors_of(rep, analysis.PASS_SELFMOD)
    assert any("ordered before target fetch" in f.message
               for f in rep.findings)


def test_selfmod_enable_gated_patch_is_clean_under_ord_wq():
    p = assembler.Program(512)
    v = p.word(7)
    wq0 = p.add_wq(4, ordering=isa.ORD_WQ, managed=True, initial_enable=1)
    wq1 = p.add_wq(4, ordering=isa.ORD_DOORBELL)
    wq0.noop()
    t = wq0.write(src=-1, dst=v)
    wq1.write_imm(dst=t.addr("src"), value=v)
    wq1.enable(wq0, upto=2)             # admits the slot after the patch
    rep = report(p)
    assert not errors_of(rep, analysis.PASS_SELFMOD)
    assert any("enable-gated" in f.message for f in rep.findings)


# ---------------------------------------------------------------------------
# pass: WAIT/ENABLE ordering
# ---------------------------------------------------------------------------

def test_order_flags_unsatisfiable_wait():
    p = assembler.Program(256)
    wq0 = p.add_wq(4)
    wq1 = p.add_wq(4)
    wq0.noop()
    wq0.noop(signaled=False)
    wq1.wait(wq0, 3)                    # at most 1 completion ever
    errs = errors_of(report(p), analysis.PASS_ORDER)
    assert len(errs) == 1 and "unsatisfiable WAIT" in errs[0].message


def test_order_flags_enable_starvation():
    p = assembler.Program(256)
    wq0 = p.add_wq(4, managed=True, initial_enable=1)
    wq1 = p.add_wq(4)
    wq0.noop()
    wq0.noop()                          # slot 1 needs an ENABLE
    wq1.enable(wq0, upto=1)             # watermark too low to admit it
    errs = errors_of(report(p), analysis.PASS_ORDER)
    assert len(errs) == 1 and "enable starvation" in errs[0].message
    assert "[1]" in errs[0].message


def test_order_flags_wait_cycle_deadlock():
    p = assembler.Program(256)
    wq0 = p.add_wq(4)
    wq1 = p.add_wq(4)
    wq0.wait(wq1, 1)
    wq0.noop()
    wq1.wait(wq0, 1)
    wq1.noop()
    errs = errors_of(report(p), analysis.PASS_ORDER)
    assert errs and "cycle" in errs[0].message


# ---------------------------------------------------------------------------
# pass: races + waivers
# ---------------------------------------------------------------------------

def _racy_prog():
    p = assembler.Program(256)
    x = p.word(0, name="x")
    wq0 = p.add_wq(2)
    wq1 = p.add_wq(2)
    wq0.write_imm(dst=x, value=1, tag="left")
    wq1.write_imm(dst=x, value=2, tag="right")
    return p


def test_race_flags_unordered_overlapping_writes():
    errs = errors_of(report(_racy_prog()), analysis.PASS_RACE)
    assert len(errs) == 1 and "race" in errs[0].message


def test_race_waiver_downgrades_and_stale_waiver_warns():
    w = analysis.Waiver(analysis.PASS_RACE, "left",
                        "last-writer-wins by design")
    rep = report(_racy_prog(), waivers=(w,))
    assert rep.ok() and len(rep.waived) == 1
    assert "last-writer-wins" in rep.waived[0].message
    stale = analysis.Waiver(analysis.PASS_RACE, "no-such-tag", "stale")
    rep2 = report(_racy_prog(), waivers=(w, stale))
    assert not rep2.ok()
    assert any(f.pass_name == analysis.PASS_WAIVER for f in rep2.warnings)


def test_wait_ordering_suppresses_race():
    p = assembler.Program(256)
    x = p.word(0)
    wq0 = p.add_wq(2)
    wq1 = p.add_wq(2)
    wq0.write_imm(dst=x, value=1)
    wq1.wait(wq0, 1)
    wq1.write_imm(dst=x, value=2)
    assert report(p).ok()


# ---------------------------------------------------------------------------
# finalize(verify=...) admission gate + build-time validation
# ---------------------------------------------------------------------------

def test_finalize_verify_raises_on_bad_program():
    with pytest.raises(analysis.VerificationError) as ei:
        _racy_prog().finalize(verify=True, name="racy")
    assert "racy" in str(ei.value) and ei.value.report.errors


def test_finalize_verify_accepts_clean_and_waivered():
    p = assembler.Program(256)
    x = p.word(0)
    p.add_wq(2).write_imm(dst=x, value=1)
    spec, state = p.finalize(verify=True)
    assert spec.mem_words == 256
    w = analysis.Waiver(analysis.PASS_RACE, "left", "benign")
    _racy_prog().finalize(verify=True, waivers=(w,))


def test_post_rejects_oversized_copy_and_bad_opcode():
    p = assembler.Program(256)
    wq = p.add_wq(4)
    with pytest.raises(ValueError, match="MAX_COPY"):
        wq.write(src=0, dst=8, ln=isa.MAX_COPY + 1)
    with pytest.raises(ValueError, match="opcode"):
        wq.post(isa.NUM_OPCODES)
    with pytest.raises(ValueError, match="MAX_SCATTER"):
        p.scatter_table(list(range(isa.MAX_SCATTER + 1)))
    assert wq.n_posted == 0             # nothing half-posted


# ---------------------------------------------------------------------------
# assembler edge cases the analyzer leans on
# ---------------------------------------------------------------------------

def test_future_wr_addr_resolves_fields():
    p = assembler.Program(256)
    wq = p.add_wq(4)
    ahead0 = {f: wq.future_wr_addr(0, f) for f in isa.FIELD_NAMES}
    ahead1_src = wq.future_wr_addr(1, "src")
    r0 = wq.noop()
    r1 = wq.noop()
    assert ahead0 == {f: r0.addr(f) for f in isa.FIELD_NAMES}
    assert ahead1_src == r1.addr("src")
    assert r0.ctrl_addr == r0.addr("ctrl")


def test_wait_for_counts_signaled_completions_only():
    p = assembler.Program(256)
    wq0 = p.add_wq(4)
    wq1 = p.add_wq(4)
    wq0.noop(signaled=False)
    ref = wq0.noop()                    # first *signaled* completion
    wq0.noop()
    w = wq1.wait_for(ref)
    assert ref.completion_count == 1
    assert wq1.wrs[w.slot]["opa"] == 1 and wq1.wrs[w.slot]["opb"] == 0
    assert report(p).ok()


# ---------------------------------------------------------------------------
# registry sweep + certificates
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def all_reports():
    return analysis.verify_all()


def test_registry_sweep_clean_or_waivered(all_reports):
    bad = {n: [str(f) for f in r.errors + r.warnings]
           for n, r in all_reports.items() if not r.ok()}
    assert not bad, f"non-waived findings: {bad}"


def test_static_wr_bound_matches_budget(all_reports):
    for name, rep in all_reports.items():
        cats = rep.certificates["budget"]
        n_posted = rep.certificates["n_posted"]
        assert sum(cats.values()) == n_posted, name
        bound = rep.certificates["static_wr_bound"]
        if rep.certificates["recycled_wqs"]:
            assert bound is None, name
        else:
            assert bound == n_posted, name


def test_static_bound_under_engine_fuel(all_reports):
    checked = 0
    for name, rep in all_reports.items():
        fuel = rep.certificates.get("fuel")
        if fuel is None:
            continue
        checked += 1
        bound = rep.certificates["static_wr_bound"]
        assert bound is not None and bound < fuel, name
    assert checked, "no builder exposed an engine fuel to check"


def test_latency_certificates_are_positive(all_reports):
    for name, rep in all_reports.items():
        c = rep.certificates
        assert c["serial_latency_us"] > 0, name
        total = sum(c["wq_latency_us"].values())
        assert c["serial_latency_us"] == pytest.approx(total, abs=0.01), name


# ---------------------------------------------------------------------------
# disassembler / CLI
# ---------------------------------------------------------------------------

def test_disassemble_renders_opcodes_and_patches():
    p = _selfmod_prog(isa.ORD_WQ)
    text = analysis.disassemble(p, name="demo")
    assert "demo" in text and "WRITE_IMM" in text
    assert "patches" in text            # the self-mod annotation


def test_cli_list_and_single_builder(capsys):
    assert analysis.main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "rpc_echo" in out and "hopscotch_migrator" in out
    assert analysis.main(["rpc_echo"]) == 0
    out = capsys.readouterr().out
    assert "SEND" in out and "0 error(s)" in out


def test_cli_sweep_exits_zero(capsys):
    assert analysis.main(["--sweep"]) == 0
    out = capsys.readouterr().out
    assert "clean-or-waivered" in out


# ---------------------------------------------------------------------------
# pass: races — the bounded CAS-retry loop (waiver-or-proof admission)
# ---------------------------------------------------------------------------

def _retry_pair():
    from repro.core import programs
    return programs.build_cas_retry_pair(attempts=2)


def test_retry_race_flagged_without_waiver():
    """Two writers' claim CASes on one cell are a genuine HB-unordered
    write/write race — the analyzer must say so when nobody vouches."""
    rep = report(_retry_pair().prog, name="retry-pair")
    errs = errors_of(rep, analysis.PASS_RACE)
    assert errs and "claim.cas" in errs[0].message


def test_retry_waiver_admits_proven_retry_shape():
    """retry_loop_waiver carries a structural proof, not just a tag
    match: both racing WRs must be claim-shaped CASes on a one-by-one
    WQ whose consecutive attempts are failure-gated.  The genuine
    retry pair satisfies it and verifies clean."""
    w = analysis.retry_loop_waiver("claim.cas", "bounded CAS-retry race")
    rep = report(_retry_pair().prog, waivers=(w,), name="retry-pair")
    assert rep.ok() and len(rep.waived) >= 1
    assert "bounded CAS-retry race" in rep.waived[0].message


def test_retry_waiver_refuses_unproven_shape():
    """Cut the claim CAS's return-old steering (src=-1): the WR still
    races, but it is no longer the retry idiom — a lost race would go
    unobserved, so nothing bounds the 'retry'.  The waiver's proof must
    fail, the race must survive as an error, and the unused waiver must
    warn stale."""
    pair = _retry_pair()
    broken = 0
    for wq in pair.prog.wqs:
        for wr in wq.wrs:
            if wr.get("tag") == "claim.cas":
                wr["src"] = -1
                broken += 1
    assert broken == 2 * pair.attempts
    w = analysis.retry_loop_waiver("claim.cas", "no longer true")
    rep = report(pair.prog, waivers=(w,), name="retry-pair-broken")
    assert not rep.ok()
    assert errors_of(rep, analysis.PASS_RACE)
    assert any(f.pass_name == analysis.PASS_WAIVER for f in rep.warnings)


def test_retry_waiver_base_class_tag_match_is_not_enough():
    """A plain Waiver on the same tag would wave the race through with
    no proof at all — retry_loop_waiver must be strictly stronger: on
    the BROKEN pair the plain waiver still (unsoundly) admits, the
    proof-carrying one refuses.  Guards against regressing the factory
    to a bare tag match."""
    pair = _retry_pair()
    for wq in pair.prog.wqs:
        for wr in wq.wrs:
            if wr.get("tag") == "claim.cas":
                wr["src"] = -1
    plain = analysis.Waiver(analysis.PASS_RACE, "claim.cas", "tag only")
    assert report(pair.prog, waivers=(plain,)).ok()
    proof = analysis.retry_loop_waiver("claim.cas", "proof")
    assert not report(pair.prog, waivers=(proof,)).ok()
