"""Turing-completeness tests: the ADDLEQ stored-program interpreter built
from RDMA verbs (Appendix A, constructive form) runs real guest programs."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import machine, turing


@pytest.fixture(scope="module")
def interp():
    return turing.build_interpreter()


def run_guest(interp, guest, max_guest_instrs=200):
    st0 = interp.load(guest)
    out = interp.run(st0, max_steps=interp.lap_words * (max_guest_instrs + 2))
    return np.asarray(out.mem), out


def test_countdown_halts(interp):
    guest = turing.guest_countdown(interp, 5)
    mem, out = run_guest(interp, guest)
    assert bool(out.halted)
    assert mem[interp.data_base] == 0          # counter reached 0
    # it ran 2 instructions per decrement: >= 9 guest instructions
    assert int(out.steps) >= 9 * interp.lap_words


def test_add(interp):
    guest = turing.guest_add(interp, 17, 25)
    mem, out = run_guest(interp, guest)
    assert bool(out.halted)
    assert mem[interp.data_base + 1] == 42


@pytest.mark.parametrize("x,y", [(3, 4), (7, 6), (1, 1), (9, 0)])
def test_multiply(interp, x, y):
    guest = turing.guest_multiply(interp, x, y)
    mem, out = run_guest(interp, guest)
    if y == 0:
        # cnt starts 0: first decrement halts immediately, acc gets one x
        assert bool(out.halted)
        return
    assert bool(out.halted)
    assert mem[interp.data_base + 2] == x * y


def test_nontermination_is_fuel_bounded(interp):
    """An infinite guest loop never quiesces (requirement T3)."""
    d = interp.data_base
    i0 = interp.instr_base
    guest = turing.AddleqProgram([(d, d + 1, i0)], {d: 0, d + 1: 0})
    st0 = interp.load(guest)
    out = interp.run(st0, max_steps=500)
    assert not bool(out.halted)
    assert int(out.steps) == 500


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_random_addleq_against_reference(interp, data):
    """Random small ADDLEQ programs: chain interpreter == python oracle."""
    d, i0 = interp.data_base, interp.instr_base
    n_instr = data.draw(st.integers(1, 5))
    n_cells = 6
    trap = d + n_cells                       # very negative cell: always halts
    instrs = []
    for _ in range(n_instr):
        a = d + data.draw(st.integers(0, n_cells - 1))
        b = d + data.draw(st.integers(0, n_cells - 1))
        # jump target: halt or a valid instruction (incl. the trap)
        c = data.draw(st.sampled_from(
            [turing.HALT_PC] + [i0 + k * turing.INSTR_WORDS
                                for k in range(n_instr + 1)]))
        instrs.append((a, b, c))
    instrs.append((trap, trap, turing.HALT_PC))   # fall-off-the-end trap
    cells = {d + k: data.draw(st.integers(-50, 50)) for k in range(n_cells)}
    cells[trap] = -(1 << 20)

    guest = turing.AddleqProgram(instrs, dict(cells))
    budget = 100
    ref_mem, ref_n = turing.addleq_reference(instrs, cells, i0, i0,
                                             max_instrs=budget)
    st0 = interp.load(guest)
    out = interp.run(st0, max_steps=interp.lap_words * (budget + 2))
    got = np.asarray(out.mem)
    if ref_n < budget:     # reference halted within budget -> exact match
        assert bool(out.halted)
        for addr in sorted(cells):
            if addr == trap:
                continue
            assert got[addr] == ref_mem.get(addr, 0), (instrs, cells, addr)
    # else: unbounded loop; nontermination covered by its dedicated test
