"""Tests for the paper's offload programs (Figs. 3, 9, 12; §3.4 recycling)."""
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import isa, machine, programs


# --- Fig. 3: RPC offload -----------------------------------------------------

def test_rpc_echo_data_dependent():
    spec, state, info = programs.build_rpc_echo()
    for arg in [0, 7, 123456]:
        s = machine.deliver(state, info["recv_wq"], [arg])
        out = machine.run(spec, s, 64)
        assert int(out.mem[info["resp"]]) == info["bias"] + arg
        assert int(out.responses) == 1


# --- Fig. 9: hash lookup -----------------------------------------------------

@pytest.mark.parametrize("parallel", [True, False])
def test_hash_lookup_hit_first_bucket(parallel):
    off = programs.build_hash_lookup(n_buckets=16, val_len=4,
                                     parallel=parallel)
    off.insert(5, [50, 51, 52, 53])
    val, out = off.get(5)
    assert val.tolist() == [50, 51, 52, 53]


@pytest.mark.parametrize("parallel", [True, False])
def test_hash_lookup_hit_second_bucket(parallel):
    """Collision: key lands in its h2 bucket (Fig. 11's worst case)."""
    off = programs.build_hash_lookup(n_buckets=16, val_len=2,
                                     parallel=parallel)
    k = 7
    # occupy k's h1 bucket with a different key whose h1 also maps there
    blocker = k + off.n_buckets
    assert off.h1(blocker) == off.h1(k)
    off.insert(blocker, [1, 1])
    assert off.h1(k) in off.kv
    off.insert(k, [70, 71])
    val, _ = off.get(k)
    assert val.tolist() == [70, 71]
    val2, _ = off.get(blocker)
    assert val2.tolist() == [1, 1]


def test_hash_lookup_miss_returns_default():
    off = programs.build_hash_lookup(n_buckets=16, val_len=2)
    off.insert(3, [30, 31])
    val, _ = off.get(4)
    assert val.tolist() == [0, 0]


def test_hash_parallel_faster_than_seq_on_collision():
    """RedN-Parallel probes buckets on independent PUs (Fig. 11)."""
    lat = {}
    for parallel in (True, False):
        off = programs.build_hash_lookup(n_buckets=16, val_len=2,
                                         parallel=parallel)
        k = 7
        blocker = k + off.n_buckets
        off.insert(blocker, [1, 1])
        off.insert(k, [70, 71])        # forced into bucket 2
        val, out = off.get(k)
        assert val.tolist() == [70, 71]
        lat[parallel] = float(machine.total_time_us(out))
    assert lat[True] < lat[False]


@settings(max_examples=15, deadline=None)
@given(st.data())
def test_hash_lookup_matches_dict(data):
    off = programs.build_hash_lookup(n_buckets=32, val_len=2)
    keys = data.draw(st.lists(st.integers(1, 1 << 20), min_size=1,
                              max_size=8, unique=True))
    inserted = {}
    for k in keys:
        if off.insert(k, [k & 0xFFFF, (k >> 4) & 0xFFFF]):
            inserted[k] = [k & 0xFFFF, (k >> 4) & 0xFFFF]
    probe = data.draw(st.sampled_from(keys + [1 << 21]))
    val, _ = off.get(probe)
    want = inserted.get(probe, [0, 0])
    assert val.tolist() == want


# --- Fig. 12: list traversal -------------------------------------------------

@pytest.mark.parametrize("use_break", [False, True])
def test_list_traversal_finds_each_position(use_break):
    off = programs.build_list_traversal(n_iters=8, val_len=2,
                                        use_break=use_break)
    items = [(10 + i, [100 + i, 200 + i]) for i in range(8)]
    off.set_list(items)
    for pos in [0, 3, 7]:
        val, _ = off.get(10 + pos)
        assert val.tolist() == [100 + pos, 200 + pos], (pos, use_break)


@pytest.mark.parametrize("use_break", [False, True])
def test_list_traversal_miss(use_break):
    off = programs.build_list_traversal(n_iters=4, val_len=2,
                                        use_break=use_break)
    off.set_list([(10 + i, [i, i]) for i in range(4)])
    val, _ = off.get(999)
    assert val.tolist() == [0, 0]


def test_list_break_saves_work():
    """§5.3: break stops iterations after the hit (>= 65% fewer WRs when
    the key is early in a long list)."""
    counts = {}
    for use_break in (False, True):
        off = programs.build_list_traversal(n_iters=8, val_len=2,
                                            use_break=use_break)
        off.set_list([(10 + i, [i, i]) for i in range(8)])
        _, out = off.get(10)        # hit at position 0
        counts[use_break] = int(out.steps)
    assert counts[True] < counts[False]


def test_list_break_latency_overhead_on_full_walk():
    """Fig. 13: with the key at the end, +break costs extra latency."""
    lat = {}
    for use_break in (False, True):
        off = programs.build_list_traversal(n_iters=8, val_len=2,
                                            use_break=use_break)
        off.set_list([(10 + i, [i, i]) for i in range(8)])
        val, out = off.get(17)      # hit at last position
        assert val.tolist() == [7, 7]
        lat[use_break] = float(machine.total_time_us(out))
    assert lat[True] > lat[False]


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_list_traversal_matches_python(data):
    n = data.draw(st.integers(2, 8))
    keys = data.draw(st.lists(st.integers(1, 10000), min_size=n, max_size=n,
                              unique=True))
    use_break = data.draw(st.booleans())
    off = programs.build_list_traversal(n_iters=n, val_len=2,
                                        use_break=use_break)
    items = [(k, [k % 97, k % 89]) for k in keys]
    off.set_list(items)
    probe = data.draw(st.sampled_from(keys + [20001]))
    val, _ = off.get(probe)
    want = next((v for k, v in items if k == probe), [0, 0])
    assert val.tolist() == want


# --- §3.4 recycled get server -------------------------------------------------

def test_recycled_server_serves_many_requests_without_rearming():
    srv = programs.build_recycled_get_server(n_buckets=16, val_len=2)
    for k in range(1, 9):
        srv.insert(k, [k * 10, k * 10 + 1])
    srv.load()
    for rounds in range(3):
        for k in range(1, 9):
            val = srv.serve(k)
            assert val.tolist() == [k * 10, k * 10 + 1], (rounds, k)
    # the loop really recycled (laps counted on-chain)
    assert int(np.asarray(srv.state.mem)[srv.laps_addr]) >= 24


def test_recycled_server_miss_then_hit():
    srv = programs.build_recycled_get_server(n_buckets=16, val_len=2)
    srv.insert(3, [33, 34])
    srv.load()
    assert srv.serve(5).tolist() == [0, 0]
    assert srv.serve(3).tolist() == [33, 34]
    assert srv.serve(5).tolist() == [0, 0]   # re-armed after the hit


# --- §5.2/§3.5 hopscotch shard server + writer --------------------------------

def test_hopscotch_server_query_zero_is_a_miss():
    """The get chain's found-flag rows are dynamic (keys != EMPTY): a
    query of 0 CAS-matches an empty bucket but must read back found=0 —
    the static flag-1 rows used to report a ghost hit."""
    import jax.numpy as jnp
    from repro.kvstore import hopscotch
    srv = programs.build_hopscotch_server(32, 2, 8)
    row = int(hopscotch.bucket_of(77, 32))
    keys = jnp.zeros((32,), jnp.int32).at[row].set(77)
    vals = jnp.zeros((32, 2), jnp.int32).at[row].set(jnp.asarray([9, 10]))
    q = jnp.asarray([0, 77, 3], jnp.int32)
    found, v = srv.get_many(keys, vals, q, hopscotch.bucket_of(q, 32))
    assert not bool(found[0]) and (np.asarray(v[0]) == 0).all()
    assert bool(found[1]) and v[1].tolist() == [9, 10]
    assert not bool(found[2])


def test_hopscotch_writer_zero_padded_request_is_inert():
    """A zero-padded receive-window slot (key 0, probe addrs 0) resolves
    against the null guard WQ, reports status 0, and commits nothing."""
    import jax.numpy as jnp
    w = programs.build_hopscotch_writer(32, 2, 8)
    keys = jnp.zeros((32,), jnp.int32).at[4].set(9)
    vals = jnp.zeros((32, 2), jnp.int32).at[4].set(jnp.asarray([1, 2]))
    pay = jnp.zeros((1 + 2 + 8,), jnp.int32)
    st = machine.deliver(w.device_state(keys, vals), w.recv_wq, pay)
    out = w.engine.run(st, 512)
    status, nk, nv = w.commit(out.mem, pay, keys, vals)
    assert int(status) == 0
    np.testing.assert_array_equal(np.asarray(nk), np.asarray(keys))
    np.testing.assert_array_equal(np.asarray(nv), np.asarray(vals))
    assert not bool(out.halted)          # quiesced, not fuel-capped
    assert int(out.steps) < 512


def test_hopscotch_writer_single_requests_all_outcomes():
    """One request per fresh context: update, first-empty claim, and the
    needs-displacement default, each via the response word + bucket addr."""
    import jax.numpy as jnp
    from repro.kvstore import hopscotch
    w = programs.build_hopscotch_writer(32, 2, 8)
    keys = jnp.zeros((32,), jnp.int32)
    vals = jnp.zeros((32, 2), jnp.int32)

    def one(k, v, tk, tv):
        pay = w.device_payloads(jnp.asarray([k], jnp.int32),
                                hopscotch.bucket_of(jnp.asarray([k]), 32),
                                jnp.asarray([v], jnp.int32))[0]
        st = machine.deliver(w.device_state(tk, tv), w.recv_wq, pay)
        out = w.engine.run(st, 512)
        return w.commit(out.mem, pay, tk, tv)

    s1, keys, vals = one(7, [70, 71], keys, vals)
    assert int(s1) == programs.SET_INSERTED
    s2, keys, vals = one(7, [72, 73], keys, vals)
    assert int(s2) == programs.SET_UPDATED
    home = int(hopscotch.bucket_of(7, 32))
    row = int(np.argmax(np.asarray(keys) == 7))
    assert (row - home) % 32 < 8
    np.testing.assert_array_equal(np.asarray(vals[row]), [72, 73])
