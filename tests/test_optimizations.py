"""Correctness tests for the beyond-paper optimization levers recorded in
EXPERIMENTS.md §Perf: rolling window caches, grad wire format, EP specs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import model as M
from repro.train import loop as loop_lib
from repro.train import optimizer as opt_lib


def test_window_cache_decode_matches_full_cache():
    """Rolling window cache must reproduce the full-cache decode logits,
    including after the buffer wraps."""
    base = registry.smoke_config("mixtral-8x7b")
    base = dataclasses.replace(base, window=8)       # tiny window: wraps
    params = M.init_params(jax.random.PRNGKey(0), base)
    rng = np.random.RandomState(3)
    b, s, n_extra = 2, 12, 6
    toks = jnp.asarray(rng.randint(1, base.vocab_size, (b, s)), jnp.int32)
    extra = jnp.asarray(rng.randint(1, base.vocab_size, (b, n_extra)),
                        jnp.int32)
    batch = {"tokens": toks}

    outs = {}
    for wincache in (False, True):
        cfg = dataclasses.replace(base, window_cache=wincache)
        s_max = s + n_extra + 2
        last, caches, lengths = M.prefill(params, batch, cfg, s_max=s_max)
        if wincache:
            # rolling caches really are window-sized
            k_shapes = [c["k"].shape[3] if False else c["k"].shape
                        for c in jax.tree_util.tree_leaves(
                            caches, is_leaf=lambda x: isinstance(x, dict)
                            and "k" in x)]
            # (G, B, KH, W, hd) stacked / (B, KH, W, hd) remainder
            assert all(sh[-2] == cfg.window for sh in k_shapes), k_shapes
        logits = []
        for i in range(n_extra):
            lengths = lengths + 1
            lg, caches = M.decode_step(params, extra[:, i], caches,
                                       lengths, cfg)
            logits.append(lg)
        outs[wincache] = jnp.stack(logits, 1)

    np.testing.assert_allclose(np.asarray(outs[True]),
                               np.asarray(outs[False]), atol=2e-3,
                               rtol=2e-3)


def test_window_cache_matches_parallel_forward():
    """Rolling cache decode == the parallel forward with SWA masking."""
    cfg = registry.smoke_config("mixtral-8x7b")
    cfg = dataclasses.replace(cfg, window=8, window_cache=True)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.RandomState(5)
    b, s = 2, 14
    toks = jnp.asarray(rng.randint(1, cfg.vocab_size, (b, s + 2)),
                       jnp.int32)
    logits_full, _, _ = M.forward(params, {"tokens": toks}, cfg)

    last, caches, lengths = M.prefill(params, {"tokens": toks[:, :s]},
                                      cfg, s_max=s + 4)
    for i in range(2):
        lengths = lengths + 1
        lg, caches = M.decode_step(params, toks[:, s + i], caches,
                                   lengths, cfg)
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(logits_full[:, -1]), atol=2e-3,
                               rtol=2e-3)


def test_kv_quant_attention_layer_exactness():
    """int8 KV cache at the attention layer: ~1% cache error, decode
    output within tight absolute tolerance of full precision."""
    from repro.models import attention as A
    cfg = registry.smoke_config("qwen3-1.7b")
    p = A.init_attention(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 10, cfg.d_model) * 0.3, jnp.float32)
    x1 = jnp.asarray(rng.randn(2, 1, cfg.d_model) * 0.3, jnp.float32)
    outs, caches = {}, {}
    for quant in (False, True):
        c = dataclasses.replace(cfg, kv_quant=quant)
        o, cache = A.apply_attention(p, x, c, "global", return_cache=True,
                                     s_max=12)
        lengths = jnp.full((2,), 11, jnp.int32)
        o1, _ = A.apply_attention_decode(p, x1, c, "global", cache,
                                         lengths=lengths)
        outs[quant], caches[quant] = np.asarray(o1), cache
    assert caches[True]["k"].dtype == jnp.int8
    deq = (np.asarray(caches[True]["k"], np.float32)
           * np.asarray(caches[True]["ks"]))
    cache_err = np.abs(deq - np.asarray(caches[False]["k"],
                                        np.float32)).max()
    assert cache_err < 0.05, cache_err          # int8 ~= 1% of range
    np.testing.assert_allclose(outs[True], outs[False], atol=0.01)


def test_kv_quant_full_model_shallow():
    """2-layer model: quantized decode logits track full precision (deep
    random nets amplify the 1% cache error chaotically, so depth is
    controlled here; the layer-level test above bounds the per-layer
    error exactly)."""
    cfg = dataclasses.replace(registry.smoke_config("qwen3-1.7b"),
                              num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(7)
    b, s = 2, 12
    toks = jnp.asarray(rng.randint(1, cfg.vocab_size, (b, s + 3)),
                       jnp.int32)
    outs = {}
    for quant in (False, True):
        c = dataclasses.replace(cfg, kv_quant=quant)
        last, caches, lengths = M.prefill(params, {"tokens": toks[:, :s]},
                                          c, s_max=s + 4)
        for i in range(3):
            lengths = lengths + 1
            lg, caches = M.decode_step(params, toks[:, s + i], caches,
                                       lengths, c)
        outs[quant] = np.asarray(lg)
    corr = np.corrcoef(outs[True].ravel(), outs[False].ravel())[0, 1]
    assert corr > 0.98, corr
    np.testing.assert_allclose(outs[True], outs[False], atol=0.05)


def test_grad_wire_and_constraint_do_not_change_training_much():
    """bf16 gradient wire: loss trajectory tracks the f32 baseline."""
    cfg = registry.smoke_config("smollm-135m")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = opt_lib.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50)
    from repro.data.pipeline import TokenPipeline
    pipe = TokenPipeline(cfg.vocab_size, 32, 8, seed=1)
    batches = [{k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
               for i in range(6)]

    traj = {}
    for wire in (None, "bfloat16"):
        p, o = params, opt_lib.init(params)
        step = jax.jit(loop_lib.make_train_step(cfg, ocfg, microbatches=2,
                                                wire_dtype=wire))
        losses = []
        for bt in batches:
            p, o, m = step(p, o, bt)
            losses.append(float(m["loss"]))
        traj[wire] = losses
    np.testing.assert_allclose(traj[None], traj["bfloat16"], rtol=0.02)


def test_int8_moment_adamw_trains():
    """8-bit Adam moments (no master): loss still descends; state is 8x
    smaller — what lets 774 B-param llama4 train on a 16 GB/chip pod."""
    cfg = registry.smoke_config("smollm-135m")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = opt_lib.AdamWConfig(lr=1e-2, warmup_steps=3, total_steps=200,
                               weight_decay=0.0, moments_dtype="int8",
                               master=False)
    opt = opt_lib.init(params, ocfg)
    assert opt.master is None
    leaves = jax.tree_util.tree_leaves(
        opt.mu, is_leaf=lambda x: isinstance(x, dict) and "q" in x)
    assert all(l["q"].dtype == jnp.int8 for l in leaves)

    from repro.data.pipeline import TokenPipeline
    step = jax.jit(loop_lib.make_train_step(cfg, ocfg))
    pipe = TokenPipeline(cfg.vocab_size, 32, 16, seed=3)
    losses = []
    for i in range(40):
        b = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert min(losses[-5:]) < losses[0] - 0.25, losses


def test_ep_param_specs_shard_experts():
    """EP rules map expert tensors' E dim to the data axis."""
    import os
    import subprocess
    import sys
    # needs >= 8 devices for a (2 data, 2 model)-divisible check; reuse
    # the spec inference logically with a fake mesh via the 1-device mesh
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.distributed import sharding as shrules
    from repro.distributed import specs as specs_lib
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    cfg = registry.smoke_config("mixtral-8x7b")
    aparams = M.abstract_params(cfg)
    with shrules.use_mesh(mesh, experts="data", fsdp=None) as rules:
        specs = specs_lib.param_specs(aparams, mesh, rules)
    moe_specs = [
        s for path, s in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, P))[0]
        if "moe" in str(path) and "w_gate" in str(path)
        and "shared" not in str(path)]
    assert moe_specs, "no moe specs found"
    # trailing dims: (..., E->data, d->None(fsdp off), f->model)
    assert all(s[-3] == "data" and s[-1] == "model" for s in moe_specs), \
        moe_specs
