"""Per-architecture smoke tests: reduced same-family config, one forward /
train-loss / decode step on CPU; asserts shapes and finiteness, and that
prefill+decode agrees with the parallel forward (cache correctness)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import model as M


def make_batch(cfg, b=2, s=16, seed=0):
    rng = np.random.RandomState(seed)
    batch = {
        "tokens": jnp.asarray(rng.randint(1, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "targets": jnp.asarray(rng.randint(1, cfg.vocab_size, (b, s)),
                               jnp.int32),
        "loss_mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.randn(b, s, cfg.frontend_dim), jnp.float32)
    if cfg.frontend == "vision" and cfg.frontend_tokens:
        batch["patches"] = jnp.asarray(
            rng.randn(b, cfg.frontend_tokens, cfg.frontend_dim),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = registry.smoke_config(arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, _, _ = M.forward(params, batch, cfg)
    b, s = batch["tokens"].shape
    extra = cfg.frontend_tokens if (cfg.frontend == "vision"
                                    and "patches" in batch) else 0
    assert logits.shape == (b, s + extra, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, metrics = M.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))
    assert float(metrics["ce"]) > 0


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_train_step_grads(arch):
    cfg = registry.smoke_config(arch)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: M.loss_fn(p, batch, cfg)[0])(params)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    norm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
               for g in flat) ** 0.5
    assert norm > 0


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_smoke_prefill_then_decode_matches_parallel(arch):
    """The cache path must reproduce the parallel forward's logits."""
    cfg = registry.smoke_config(arch)
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    b, s = 2, 12
    batch = make_batch(cfg, b=b, s=s)

    # parallel forward over s+2 tokens
    rng = np.random.RandomState(7)
    extra_toks = jnp.asarray(rng.randint(1, cfg.vocab_size, (b, 2)),
                             jnp.int32)
    full = dict(batch)
    full["tokens"] = jnp.concatenate([batch["tokens"], extra_toks], axis=1)
    logits_full, _, _ = M.forward(params, full, cfg)

    # prefill s tokens, then decode the 2 extra
    extra_front = cfg.frontend_tokens if (cfg.frontend == "vision"
                                          and "patches" in batch) else 0
    s_max = s + extra_front + 4
    last, caches, lengths = M.prefill(params, batch, cfg, s_max=s_max)
    enc_lengths = (jnp.full((b,), batch["frames"].shape[1], jnp.int32)
                   if cfg.is_encdec else None)
    outs = []
    for i in range(2):
        lengths = lengths + 1
        lg, caches = M.decode_step(params, extra_toks[:, i], caches,
                                   lengths, cfg, enc_lengths=enc_lengths)
        outs.append(lg)

    extra = cfg.frontend_tokens if (cfg.frontend == "vision"
                                    and "patches" in batch) else 0
    want0 = logits_full[:, extra + s - 1 + 1]      # logits at new token 1
    want1 = logits_full[:, extra + s - 1 + 2]
    tol = 2e-2 if cfg.dtype == "bfloat16" else 2e-3
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(want0),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(outs[1]), np.asarray(want1),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("arch", registry.ARCHS)
def test_abstract_params_match_concrete(arch):
    cfg = registry.smoke_config(arch)
    abstract = M.abstract_params(cfg)
    concrete = M.init_params(jax.random.PRNGKey(0), cfg)
    ab = jax.tree_util.tree_map(lambda a: (a.shape, str(a.dtype)), abstract)
    co = jax.tree_util.tree_map(lambda a: (a.shape, str(a.dtype)), concrete)
    assert ab == co


def test_full_configs_match_assignment():
    """Spot-check the exact assigned numbers."""
    c = registry.get_config("mixtral-8x7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.num_experts, c.experts_per_token) == \
        (32, 4096, 32, 8, 14336, 32000, 8, 2)
    c = registry.get_config("llama4-maverick-400b-a17b")
    assert (c.num_layers, c.d_model, c.num_heads, c.vocab_size,
            c.num_experts, c.experts_per_token) == \
        (48, 5120, 40, 202048, 128, 1)
    c = registry.get_config("qwen3-1.7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.qk_norm) == (28, 2048, 16, 8, 6144, 151936,
                                         True)
    c = registry.get_config("smollm-135m")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (30, 576, 9, 3, 1536, 49152)
    c = registry.get_config("glm4-9b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (40, 4096, 32, 2, 13696, 151552)
    c = registry.get_config("gemma3-1b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (26, 1152, 4, 1, 6912, 262144)
    assert c.layer_pattern.count("local") == 5
    c = registry.get_config("seamless-m4t-medium")
    assert (c.num_layers, c.num_encoder_layers, c.d_model, c.num_heads,
            c.d_ff, c.vocab_size) == (12, 12, 1024, 16, 4096, 256206)
    c = registry.get_config("phi-3-vision-4.2b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (32, 3072, 32, 32, 8192, 32064)
    c = registry.get_config("rwkv6-7b")
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size) == \
        (32, 4096, 14336, 65536)
    assert c.layer_pattern == ("rwkv",)
    c = registry.get_config("recurrentgemma-9b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (38, 4096, 16, 1, 12288, 256000)
    assert c.layer_pattern == ("recurrent", "recurrent", "local")


def test_smollm_param_count_near_135m():
    c = registry.get_config("smollm-135m")
    n = c.total_params
    assert 120e6 < n < 180e6, n


def test_mixtral_param_counts():
    c = registry.get_config("mixtral-8x7b")
    assert 40e9 < c.total_params < 52e9, c.total_params
    assert 10e9 < c.active_params < 16e9, c.active_params
