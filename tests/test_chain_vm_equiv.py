"""Property test: the Pallas chain_vm executor and the core multi-WQ
machine agree on random single-WQ straight-line programs — the kernel
really is a NIC PU running the same ISA."""
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import assembler, isa, machine
from repro.kernels.chain_vm import ops as chain_ops


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_chain_vm_matches_core_machine_on_random_programs(data):
    n_data = 8
    n_wrs = data.draw(st.integers(1, 8))
    p = assembler.Program(512)
    cells = [p.word(data.draw(st.integers(-50, 50))) for _ in range(n_data)]
    wq = p.add_wq(n_wrs + 1)

    for _ in range(n_wrs):
        op = data.draw(st.sampled_from(
            ["write", "write_imm", "read", "cas", "add", "max", "min"]))
        a = data.draw(st.sampled_from(cells))
        b = data.draw(st.sampled_from(cells))
        v = data.draw(st.integers(-50, 50))
        if op == "write":
            wq.write(src=a, dst=b, ln=1)
        elif op == "write_imm":
            wq.write_imm(dst=b, value=v)
        elif op == "read":
            wq.read(src=a, dst=b, ln=1)
        elif op == "cas":
            wq.cas(dst=b, old=v, new=data.draw(st.integers(-50, 50)))
        elif op == "add":
            wq.add(dst=b, addend=v)
        elif op == "max":
            wq.max_(dst=b, operand=v)
        else:
            wq.min_(dst=b, operand=v)
    wq.halt()

    spec, st0 = p.finalize()
    out_core = machine.run(spec, st0, max_steps=n_wrs + 2)
    # keep the MAX_COPY guard words: copy verbs near the end of memory
    # clamp differently without them
    mem0 = np.asarray(st0.mem)
    out_kern = chain_ops.run_chains(
        jnp.asarray(mem0[None]), wq_base=spec.wq_bases[0],
        n_wrs=spec.wq_sizes[0], max_steps=n_wrs + 2, impl="ref")
    core_mem = np.asarray(out_core.mem)
    kern_mem = np.asarray(out_kern[0])
    np.testing.assert_array_equal(core_mem, kern_mem)
