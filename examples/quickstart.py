"""Quickstart: RedN chains in five minutes.

Builds the paper's core constructs and runs them on the chain VM:
  1. a conditional (Fig. 4)       — CAS rewrites a NOOP into a WRITE
  2. an offloaded RPC (Fig. 3)    — client SEND triggers a posted chain
  3. a hash-table get (Fig. 9)    — the full self-modifying lookup
  4. WQ recycling (§3.4)          — a loop with no CPU involvement

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import assembler, constructs, isa, machine, programs


def demo_if():
    print("== Fig. 4: if (x == y) via self-modifying CAS ==")
    for x, y in [(7, 7), (7, 8)]:
        p = assembler.Program(512)
        one, resp = p.word(1), p.word(0)
        mod = p.add_wq(4, managed=True, ordering=isa.ORD_DOORBELL)
        ctl = p.add_wq(8)
        constructs.emit_if(ctl, mod, x=x, y=y, then_src=one, then_dst=resp)
        spec, st = p.finalize()
        out = machine.run(spec, st, 64)
        print(f"  if ({x} == {y}) -> response={int(out.mem[resp])} "
              f"({float(machine.total_time_us(out)):.2f} modeled us)")


def demo_rpc():
    print("== Fig. 3: RPC handler offloaded to the 'NIC' ==")
    spec, state, info = programs.build_rpc_echo(bias=1000)
    for arg in (42, 999):
        s = machine.deliver(state, info["recv_wq"], [arg])
        out = machine.run(spec, s, 64)
        print(f"  rpc({arg}) = {int(out.mem[info['resp']])}")


def demo_hash():
    print("== Fig. 9: hash-table get, zero CPU on the serving path ==")
    off = programs.build_hash_lookup(n_buckets=32, val_len=2)
    off.insert(1001, [11, 22])
    off.insert(2002, [33, 44])
    for k in (1001, 2002, 3003):
        val, out = off.get(k)
        print(f"  get({k}) -> {val.tolist()} "
              f"({float(machine.total_time_us(out)):.2f} modeled us, "
              f"{int(out.steps)} WRs)")
    vals, _ = off.get_many([1001, 2002, 3003])
    print(f"  get_many([1001, 2002, 3003]) -> {vals.tolist()} "
          f"(one vmapped run)")


def demo_recycling():
    print("== §3.4: WQ recycling — the chain never stops ==")
    srv = programs.build_recycled_get_server(n_buckets=16, val_len=2)
    srv.insert(5, [50, 51])
    srv.load()
    for rnd in range(3):
        v = srv.serve(5)
        laps = int(np.asarray(srv.state.mem)[srv.laps_addr])
        print(f"  round {rnd}: get(5)={v.tolist()}  chain laps={laps}")


if __name__ == "__main__":
    demo_if()
    demo_rpc()
    demo_hash()
    demo_recycling()
    print("done.")
