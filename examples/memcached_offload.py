"""Memcached with RedN offload (paper §5.4–§5.6) — the flagship use case.

A sharded KV store serves zipf-distributed gets through the paper's three
paths (redn / one-sided / two-sided), then demonstrates the two systems
properties RedN buys: per-tenant isolation and host-crash survival.

Run: PYTHONPATH=src python examples/memcached_offload.py
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.data.pipeline import kv_request_stream
from repro.kvstore import store as kv_store
from repro.rdma import failure, isolation


def main():
    print("== populate (host set path, like the paper) ==")
    kv = kv_store.ShardedKV.build(n_shards=1, buckets_per_shard=1024,
                                  val_words=4)
    n_keys = 400
    for k in range(1, n_keys + 1):
        if not kv.set(k, [k, k * 2, k * 3, k * 5]):
            raise RuntimeError(f"seeding key {k} needs a resize")
    dk, dv = kv.device_arrays()
    mesh = Mesh(np.array(jax.devices()[:1]), ("kv",))

    print("== serve 4 batches of 64 zipf gets via each path ==")
    stream = kv_request_stream(n_keys, 64, seed=1)
    for method in ("redn", "one_sided", "two_sided"):
        hits = 0
        for _ in range(4):
            _, keys = next(stream)
            q = jnp.asarray(keys[None])
            res = kv_store.sharded_get(mesh, "kv", dk, dv, q, method=method)
            hits += int(jnp.sum(res.found))
        print(f"  {method:10s}: {hits}/256 hits, "
              f"{kv_store.RTTS[method]} RTT"
              f"{' + host CPU' if kv_store.HOST_SERVICE[method] else ''}")

    print("== isolation (§5.5): a greedy tenant cannot starve others ==")
    buckets = isolation.init(n_clients=2, burst=8.0)
    greedy = jnp.zeros(32, jnp.int32)            # tenant 0: 32 requests
    polite = jnp.ones(4, jnp.int32)              # tenant 1: 4 requests
    buckets, ok_greedy = isolation.admit(buckets, greedy, 0.0, 0.01, 8.0)
    buckets, ok_polite = isolation.admit(buckets, polite, 0.0, 0.01, 8.0)
    print(f"  greedy tenant admitted {int(ok_greedy.sum())}/32, "
          f"polite tenant admitted {int(ok_polite.sum())}/4")

    print("== failure resiliency (§5.6): kill the host, keep serving ==")
    svc = failure.DeviceResidentService.start(
        [(k, [k, k + 1]) for k in range(1, 9)])
    print(f"  get(3) = {svc.get(3).tolist()}  (host alive: "
          f"{svc.host_alive()})")
    svc.crash_host()
    print(f"  get(5) = {svc.get(5).tolist()}  (host alive: "
          f"{svc.host_alive()})  <- zero-interruption")
    batch = svc.get_many([1, 2, 3, 4]).tolist()
    print(f"  get_many([1..4]) = {batch}  <- one device call, host dead")
    svc.restart_host()
    print(f"  vanilla Memcached would have been down "
          f"{svc.cold_restart_downtime_s():.2f}s")


if __name__ == "__main__":
    main()
