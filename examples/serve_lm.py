"""End-to-end driver: serve a small LM with batched requests.

The decode path's KV cache is the RedN distributed KV store (DESIGN.md):
every decode step is a batched *get* against the cache.  The engine also
exercises isolation (token buckets per tenant) and failure resiliency
(the host driver dies mid-serving; device state keeps decoding).

Run: PYTHONPATH=src python examples/serve_lm.py [--steps 24]
"""
import argparse

import jax
import numpy as np

from repro.configs import registry
from repro.models import model as M
from repro.serve import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--arch", default="qwen3-1.7b")
    args = ap.parse_args()

    cfg = registry.smoke_config(args.arch)
    print(f"serving {cfg.name}: {cfg.num_layers}L d={cfg.d_model}")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(cfg, params, s_max=64, n_slots=8, n_clients=3,
                      rate_per_us=0.5, burst=4.0)

    # admission: 3 tenants, tenant 0 is greedy
    requests = [(0, 11), (0, 12), (0, 13), (0, 14), (0, 15),
                (1, 21), (2, 31)]
    admitted = eng.admit([c for c, _ in requests])
    slot = 0
    for ok, (client, token) in zip(admitted, requests):
        status = "admitted" if ok else "THROTTLED"
        print(f"  tenant {client} request(token={token}): {status}")
        if ok and slot < eng.n_slots:
            eng.add_request(slot, client, token)
            slot += 1

    print(f"decoding {args.steps} steps for {slot} sequences ...")
    for i in range(args.steps):
        toks = eng.step()
        if i == args.steps // 2:
            eng.crash_host_driver()
            print(f"  step {i}: HOST DRIVER CRASHED "
                  f"(alive={eng.host_alive()}) — serving continues")
        if i % 8 == 0:
            print(f"  step {i}: tokens={toks[:slot].tolist()}")
    eng.restart_host_driver()
    print(f"stats: {eng.stats}")
    print("done — zero decode interruptions through the crash.")


if __name__ == "__main__":
    main()
