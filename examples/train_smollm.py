"""End-to-end training driver: SmolLM-family model, a few hundred steps,
with checkpoints, a simulated crash, and bit-exact resume.

Full smollm-135m trains the same way on a real mesh (see
src/repro/launch/train.py); on this CPU container the default is a reduced
width so a few hundred steps finish in minutes.

Run: PYTHONPATH=src python examples/train_smollm.py [--steps 200]
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.data.pipeline import TokenPipeline
from repro.distributed.fault import TrainController
from repro.models import model as M
from repro.train import loop as loop_lib
from repro.train import optimizer as opt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full-size", action="store_true",
                    help="use the real 135M config (slow on CPU)")
    args = ap.parse_args()

    cfg = (registry.get_config("smollm-135m") if args.full_size
           else registry.smoke_config("smollm-135m"))
    cfg = dataclasses.replace(cfg, dtype="float32", remat="none")
    print(f"training {cfg.name}: ~{cfg.total_params/1e6:.1f}M params")

    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = opt_lib.AdamWConfig(lr=6e-3, warmup_steps=20,
                               total_steps=args.steps, weight_decay=0.01)
    opt = opt_lib.init(params)
    step = jax.jit(loop_lib.make_train_step(cfg, ocfg))
    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch, seed=0)

    def batch_fn(i):
        return {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}

    ckpt_dir = tempfile.mkdtemp(prefix="smollm_ckpt_")
    ctl = TrainController(
        step_fn=lambda p, o, b: step(p, o, b),
        batch_fn=batch_fn, ckpt_dir=ckpt_dir, ckpt_every=25)

    crash_at = args.steps // 2
    print(f"running to step {crash_at}, then simulating a node failure...")
    try:
        ctl.run(params, opt, 0, args.steps, crash_at=crash_at)
    except RuntimeError as e:
        print(f"  {e}")

    resumed = ctl.resume(jax.eval_shape(lambda: params),
                         jax.eval_shape(lambda: opt))
    params, opt, at = resumed
    print(f"resumed from checkpoint at step {at}; continuing to "
          f"{args.steps}")
    params, opt, _ = ctl.run(params, opt, at, args.steps)

    losses = []
    for i in range(args.steps - 5, args.steps):
        _, _, m = step(params, opt, batch_fn(i))
        losses.append(float(m["loss"]))
    print(f"final loss (eval on last batches): {sum(losses)/5:.3f}")
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
