"""Turing-completeness demo: a stored-program computer made of RDMA verbs.

Loads ADDLEQ guest programs into the chain interpreter (Appendix A made
constructive) and runs them: every guest instruction executes as ~26 RDMA
verbs — indirect mov fetches, a patched ADD, Calc-verb clamps for the
conditional branch, and WQ recycling for nontermination.

Run: PYTHONPATH=src python examples/turing_demo.py
"""
import numpy as np

from repro.core import turing


def main():
    interp = turing.build_interpreter()
    print(f"interpreter: {interp.lap_words} verbs per guest instruction")

    print("== guest: add(17, 25) ==")
    st = interp.load(turing.guest_add(interp, 17, 25))
    out = interp.run(st, max_steps=interp.lap_words * 20)
    mem = np.asarray(out.mem)
    print(f"  result = {mem[interp.data_base + 1]}   "
          f"(halted={bool(out.halted)}, verbs executed={int(out.steps)})")

    print("== guest: multiply(7, 6) via a guest-level loop ==")
    st = interp.load(turing.guest_multiply(interp, 7, 6))
    out = interp.run(st, max_steps=interp.lap_words * 100)
    mem = np.asarray(out.mem)
    print(f"  result = {mem[interp.data_base + 2]}   "
          f"(halted={bool(out.halted)}, verbs executed={int(out.steps)})")

    print("== guest: countdown(5) — conditional branch + halt ==")
    st = interp.load(turing.guest_countdown(interp, 5))
    out = interp.run(st, max_steps=interp.lap_words * 40)
    mem = np.asarray(out.mem)
    print(f"  counter = {mem[interp.data_base]}   "
          f"(halted={bool(out.halted)})")

    print("== nontermination (T3): an infinite guest loop ==")
    d, i0 = interp.data_base, interp.instr_base
    st = interp.load(turing.AddleqProgram([(d, d + 1, i0)],
                                          {d: 0, d + 1: 0}))
    out = interp.run(st, max_steps=500)
    print(f"  after 500 fuel: halted={bool(out.halted)} (still running)")


if __name__ == "__main__":
    main()
